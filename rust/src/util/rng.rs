//! Deterministic pseudo-random number generation.
//!
//! Every stochastic component in the repo (dataset synthesis, k-means++
//! seeding, weight init, RSA blinding nonces for *benchmarks*) draws from
//! this seeded generator so experiments are exactly reproducible. The core
//! is xoshiro256** seeded via SplitMix64 — the standard, well-tested
//! construction (Blackman & Vigna).

/// xoshiro256** PRNG with SplitMix64 seeding.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 expansion of the seed into the xoshiro state.
        let mut sm = seed;
        let mut next_sm = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        let s = [next_sm(), next_sm(), next_sm(), next_sm()];
        Rng { s }
    }

    /// Derive an independent child generator (stable split by label).
    pub fn fork(&mut self, label: u64) -> Rng {
        Rng::new(self.next_u64() ^ label.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Snapshot the raw xoshiro state — the checkpoint/resume currency of
    /// the serve supervisor. Restoring via [`Rng::from_state`] continues
    /// the exact stream.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild a generator from a [`Rng::state`] snapshot.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }

    /// Next raw 64-bit value.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform u64 in `[0, bound)` without modulo bias (Lemire reduction).
    pub fn below(&mut self, bound: u64) -> u64 {
        assert!(bound > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (bound as u128);
        let mut l = m as u64;
        if l < bound {
            let t = bound.wrapping_neg() % bound;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (bound as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Uniform usize in `[0, bound)`.
    pub fn below_usize(&mut self, bound: usize) -> usize {
        self.below(bound as u64) as usize
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f32 in `[0, 1)`.
    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in `[lo, hi)`.
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Standard normal via Box–Muller (cached second value dropped for
    /// simplicity — synthesis is not a hot path).
    pub fn gaussian(&mut self) -> f64 {
        let u1 = (1.0 - self.f64()).max(f64::MIN_POSITIVE); // avoid ln(0)
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Standard normal as f32.
    pub fn gaussian_f32(&mut self) -> f32 {
        self.gaussian() as f32
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below_usize(i + 1);
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "sample {k} from {n}");
        let mut chosen = std::collections::HashSet::with_capacity(k);
        let mut out = Vec::with_capacity(k);
        for j in (n - k)..n {
            let t = self.below_usize(j + 1);
            let pick = if chosen.contains(&t) { j } else { t };
            chosen.insert(pick);
            out.push(pick);
        }
        out
    }

    /// Fill a byte buffer.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_instances() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        assert_ne!(
            (0..8).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..8).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(3);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10);
            assert!(v < 10);
            seen[v as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "all residues hit");
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Rng::new(4);
        for _ in 0..1000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Rng::new(5);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.05, "mean {mean}");
        assert!((var - 1.0).abs() < 0.1, "var {var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(6);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>(), "astronomically unlikely");
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(8);
        let s = r.sample_indices(100, 30);
        assert_eq!(s.len(), 30);
        let set: std::collections::HashSet<_> = s.iter().collect();
        assert_eq!(set.len(), 30);
        assert!(s.iter().all(|&i| i < 100));
    }

    #[test]
    fn fork_decorrelates() {
        let mut r = Rng::new(9);
        let mut c1 = r.fork(1);
        let mut c2 = r.fork(2);
        assert_ne!(c1.next_u64(), c2.next_u64());
    }
}
