//! Capped, jittered exponential backoff — the one retry-delay
//! implementation in the crate.
//!
//! Used by the TCP dial path (`net::tcp`), the send-side redial, and the
//! serve supervisor's between-attempt waits, so every retry loop shares
//! the same schedule semantics: the *raw* delay doubles from
//! [`BackoffConfig::base`] until it pins at [`BackoffConfig::cap`], and
//! each attempt's actual sleep is jittered deterministically (seeded, so
//! runs are reproducible) into `[raw/2, raw]`. After
//! [`BackoffConfig::max_attempts`] delays the schedule is exhausted and
//! [`Backoff::next_delay`] returns `None` — the caller gives up.

use std::time::Duration;

/// Schedule parameters. `Copy` so configs embed it freely.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct BackoffConfig {
    /// First raw delay; doubles each attempt.
    pub base: Duration,
    /// Ceiling for the raw delay.
    pub cap: Duration,
    /// How many delays the schedule yields before giving up.
    pub max_attempts: u32,
    /// Jitter seed: same seed, same schedule (determinism contract).
    pub seed: u64,
}

impl Default for BackoffConfig {
    fn default() -> Self {
        BackoffConfig {
            base: Duration::from_millis(10),
            cap: Duration::from_secs(1),
            max_attempts: 5,
            seed: 0,
        }
    }
}

impl BackoffConfig {
    /// The raw (pre-jitter) delay for `attempt` (0-based): `base * 2^n`,
    /// saturating, capped at `cap`. Pure, so tests can pin the schedule.
    pub fn raw_delay(&self, attempt: u32) -> Duration {
        let base = self.base.as_nanos() as u64;
        let shifted = if attempt >= 63 { u64::MAX } else { base.saturating_mul(1u64 << attempt) };
        Duration::from_nanos(shifted.min(self.cap.as_nanos() as u64))
    }
}

/// SplitMix64 finalizer — the jitter hash. Private but exercised through
/// the determinism tests below.
fn mix(mut z: u64) -> u64 {
    z = z.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateful schedule iterator.
#[derive(Clone, Debug)]
pub struct Backoff {
    cfg: BackoffConfig,
    attempt: u32,
}

impl Backoff {
    pub fn new(cfg: BackoffConfig) -> Backoff {
        Backoff { cfg, attempt: 0 }
    }

    /// Attempts consumed so far.
    pub fn attempt(&self) -> u32 {
        self.attempt
    }

    /// The next jittered delay, or `None` when the schedule is exhausted.
    /// Integer arithmetic throughout: `raw/2 + (hash mod (raw/2 + 1))`,
    /// i.e. uniformly in `[raw/2, raw]`.
    pub fn next_delay(&mut self) -> Option<Duration> {
        if self.attempt >= self.cfg.max_attempts {
            return None;
        }
        let raw = self.cfg.raw_delay(self.attempt).as_nanos() as u64;
        let half = raw / 2;
        let span = raw - half + 1;
        let jit = mix(self.cfg.seed ^ u64::from(self.attempt).wrapping_mul(0xA076_1D64_78BD_642F));
        let delay = half + jit % span;
        self.attempt += 1;
        Some(Duration::from_nanos(delay))
    }
}

/// Run `op` under the schedule: call it for attempt 0, and after each
/// failure sleep the next jittered delay and call it again, until the
/// schedule is exhausted — then return the last error. This is the shared
/// dial/redial retry loop.
pub fn retry<T>(
    cfg: BackoffConfig,
    mut op: impl FnMut(u32) -> crate::error::Result<T>,
) -> crate::error::Result<T> {
    let mut backoff = Backoff::new(cfg);
    loop {
        let attempt = backoff.attempt();
        match op(attempt) {
            Ok(v) => return Ok(v),
            Err(e) => match backoff.next_delay() {
                Some(d) => std::thread::sleep(d),
                None => return Err(e),
            },
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> BackoffConfig {
        BackoffConfig {
            base: Duration::from_millis(10),
            cap: Duration::from_millis(160),
            max_attempts: 7,
            seed: 42,
        }
    }

    /// The capped raw schedule, pinned exactly: doubling then flat at cap.
    #[test]
    fn raw_schedule_is_pinned() {
        let c = cfg();
        let want_ms: [u64; 7] = [10, 20, 40, 80, 160, 160, 160];
        for (n, want) in want_ms.iter().enumerate() {
            assert_eq!(
                c.raw_delay(n as u32),
                Duration::from_millis(*want),
                "attempt {n}"
            );
        }
        // Saturation far past the doubling range stays at cap.
        assert_eq!(c.raw_delay(63), Duration::from_millis(160));
        assert_eq!(c.raw_delay(200), Duration::from_millis(160));
    }

    /// Jittered delays stay within [raw/2, raw], the schedule yields
    /// exactly `max_attempts` delays, and the same seed reproduces the
    /// same schedule while a different seed diverges.
    #[test]
    fn jitter_is_bounded_deterministic_and_exhausts() {
        let c = cfg();
        let mut a = Backoff::new(c);
        let mut b = Backoff::new(c);
        let mut delays = Vec::new();
        for n in 0..c.max_attempts {
            let raw = c.raw_delay(n);
            let d = a.next_delay().expect("schedule not exhausted yet");
            assert_eq!(b.next_delay(), Some(d), "same seed must reproduce attempt {n}");
            assert!(d >= raw / 2 && d <= raw, "attempt {n}: {d:?} outside [{:?}, {raw:?}]", raw / 2);
            delays.push(d);
        }
        assert_eq!(a.next_delay(), None, "exhausted after max_attempts");
        assert_eq!(a.attempt(), c.max_attempts);

        let mut other = Backoff::new(BackoffConfig { seed: 43, ..c });
        let diverged = (0..c.max_attempts).any(|n| other.next_delay() != Some(delays[n as usize]));
        assert!(diverged, "different seed should jitter differently");
    }

    /// `retry` returns the first success and stops retrying; an op that
    /// never succeeds surfaces its last error after max_attempts+1 calls.
    #[test]
    fn retry_counts_attempts() {
        let c = BackoffConfig {
            base: Duration::from_micros(1),
            cap: Duration::from_micros(4),
            max_attempts: 3,
            seed: 7,
        };
        let mut calls = 0;
        let ok: crate::error::Result<u32> = retry(c, |attempt| {
            calls += 1;
            if attempt == 2 {
                Ok(attempt)
            } else {
                Err(crate::error::Error::Net("nope".into()))
            }
        });
        assert_eq!(ok.unwrap(), 2);
        assert_eq!(calls, 3);

        let mut calls = 0;
        let err: crate::error::Result<()> = retry(c, |_| {
            calls += 1;
            Err(crate::error::Error::Net("always".into()))
        });
        assert!(err.is_err());
        assert_eq!(calls, 4, "initial call + max_attempts retries");
    }
}
