//! Wall-clock timing helpers and a hierarchical phase recorder.

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Simple stopwatch.
#[derive(Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn elapsed_ms(&self) -> f64 {
        self.elapsed().as_secs_f64() * 1e3
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

impl Default for Stopwatch {
    fn default() -> Self {
        Self::start()
    }
}

/// Accumulates named phase durations (align / coreset / train, per-step
/// breakdowns, ...). Cheap enough for per-batch use.
#[derive(Debug, Default, Clone)]
pub struct PhaseTimer {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl PhaseTimer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under `name`.
    pub fn time<T>(&mut self, name: &str, f: impl FnOnce() -> T) -> T {
        let t = Instant::now();
        let out = f();
        self.add(name, t.elapsed());
        out
    }

    /// Record an externally measured duration.
    pub fn add(&mut self, name: &str, d: Duration) {
        *self.totals.entry(name.to_string()).or_default() += d;
        *self.counts.entry(name.to_string()).or_default() += 1;
    }

    pub fn total(&self, name: &str) -> Duration {
        self.totals.get(name).copied().unwrap_or_default()
    }

    pub fn count(&self, name: &str) -> u64 {
        self.counts.get(name).copied().unwrap_or_default()
    }

    /// Merge another recorder into this one.
    pub fn merge(&mut self, other: &PhaseTimer) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, v) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *v;
        }
    }

    /// Human-readable summary sorted by total time, descending.
    pub fn report(&self) -> String {
        let mut rows: Vec<_> = self.totals.iter().collect();
        rows.sort_by(|a, b| b.1.cmp(a.1));
        let mut s = String::new();
        for (k, v) in rows {
            s.push_str(&format!(
                "{:<28} {:>10.3}s  x{}\n",
                k,
                v.as_secs_f64(),
                self.counts[k]
            ));
        }
        s
    }

    pub fn phases(&self) -> impl Iterator<Item = (&str, Duration)> {
        self.totals.iter().map(|(k, v)| (k.as_str(), *v))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_ms() >= 4.0);
    }

    #[test]
    fn phase_timer_accumulates() {
        let mut pt = PhaseTimer::new();
        pt.time("a", || std::thread::sleep(Duration::from_millis(2)));
        pt.time("a", || std::thread::sleep(Duration::from_millis(2)));
        pt.add("b", Duration::from_millis(10));
        assert_eq!(pt.count("a"), 2);
        assert!(pt.total("a") >= Duration::from_millis(3));
        assert_eq!(pt.total("b"), Duration::from_millis(10));
        assert!(pt.report().contains('a'));
    }

    #[test]
    fn merge_sums() {
        let mut a = PhaseTimer::new();
        a.add("x", Duration::from_millis(1));
        let mut b = PhaseTimer::new();
        b.add("x", Duration::from_millis(2));
        a.merge(&b);
        assert_eq!(a.total("x"), Duration::from_millis(3));
        assert_eq!(a.count("x"), 2);
    }
}
