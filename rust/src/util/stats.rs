//! Small statistics helpers used by the bench harness and metrics.

/// Mean of a slice (0 for empty).
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Sample standard deviation (0 for n < 2).
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / (xs.len() - 1) as f64).sqrt()
}

/// p-th percentile (0..=100) by linear interpolation on a sorted copy.
pub fn percentile(xs: &[f64], p: f64) -> f64 {
    assert!((0.0..=100.0).contains(&p));
    if xs.is_empty() {
        return 0.0;
    }
    let mut v = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let rank = p / 100.0 * (v.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = rank - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median.
pub fn median(xs: &[f64]) -> f64 {
    percentile(xs, 50.0)
}

/// Min/max (NaN-free input assumed).
pub fn min_max(xs: &[f64]) -> (f64, f64) {
    xs.iter().fold((f64::INFINITY, f64::NEG_INFINITY), |(lo, hi), &x| {
        (lo.min(x), hi.max(x))
    })
}

/// Argmax of an f32 slice (first winner on ties).
pub fn argmax_f32(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Argmin of an f32 slice (first winner on ties).
pub fn argmin_f32(xs: &[f32]) -> usize {
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x < xs[best] {
            best = i;
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_std() {
        let xs = [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0];
        assert!((mean(&xs) - 5.0).abs() < 1e-12);
        assert!((std_dev(&xs) - 2.138089935).abs() < 1e-6);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 100.0), 4.0);
        assert!((median(&xs) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn argminmax() {
        assert_eq!(argmax_f32(&[1.0, 5.0, 3.0]), 1);
        assert_eq!(argmin_f32(&[1.0, 5.0, -3.0]), 2);
        assert_eq!(argmax_f32(&[2.0, 2.0]), 0, "tie -> first");
    }

    #[test]
    fn empty_safe() {
        assert_eq!(mean(&[]), 0.0);
        assert_eq!(std_dev(&[1.0]), 0.0);
    }
}
