//! # TreeCSS — an efficient framework for vertical federated learning
//!
//! Rust + JAX + Pallas reproduction of *TreeCSS: An Efficient Framework for
//! Vertical Federated Learning* (Zhang et al., 2024). The crate is the L3
//! coordinator of a three-layer stack (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — party endpoints over a pluggable transport
//!   ([`net::transport`]), Tree/Path/Star-MPSI, RSA/OT two-party PSI,
//!   Paillier HE, Cluster-Coreset orchestration and the SplitNN training
//!   loop. Python never runs on this path.
//! * **L2** — JAX compute graphs (`python/compile/model.py`), AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) inside those graphs.
//!
//! The front door is the session builder
//! ([`coordinator::Pipeline::builder`]): configure a framework variant,
//! build a [`coordinator::Session`] that owns a metered wire — in-process
//! channels, or real localhost TCP sockets via
//! [`coordinator::TransportKind::Tcp`], with `--distributed` hosting each
//! client's endpoint in its own OS process — and run the paper's
//! lifecycle — **align** (Tree-MPSI over the clients' sample indicators,
//! every protocol message an envelope on the transport) → **coreset**
//! (per-client K-Means, HE-sealed cluster tuples routed via the
//! aggregator, per-(CT,label) selection, re-weighting) → **train**
//! (weighted SplitNN as a party protocol: activations, gradients, and
//! loss control cross the same transport under `train/fwd`,
//! `train/grad`, `train/loss` — [`splitnn::protocol::train_over`], with
//! [`splitnn::trainer::train_local`] as the bitwise-pinned in-process
//! reference).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod coreset;
pub mod crypto;
pub mod data;
pub mod error;
pub mod ml;
pub mod net;
pub mod parties;
pub mod psi;
pub mod runtime;
pub mod splitnn;
pub mod util;

pub use error::{Error, Result};
