//! # TreeCSS — an efficient framework for vertical federated learning
//!
//! Rust + JAX + Pallas reproduction of *TreeCSS: An Efficient Framework for
//! Vertical Federated Learning* (Zhang et al., 2024). The crate is the L3
//! coordinator of a three-layer stack (see `DESIGN.md`):
//!
//! * **L3 (this crate)** — parties, transport, Tree/Path/Star-MPSI,
//!   RSA/OT two-party PSI, Paillier HE, Cluster-Coreset orchestration and
//!   the SplitNN training loop. Python never runs on this path.
//! * **L2** — JAX compute graphs (`python/compile/model.py`), AOT-lowered
//!   to `artifacts/*.hlo.txt`.
//! * **L1** — Pallas kernels (`python/compile/kernels/`) inside those graphs.
//!
//! The end-to-end lifecycle mirrors the paper: **align** (Tree-MPSI over the
//! clients' sample indicators) → **coreset** (per-client K-Means, cluster
//! tuples, per-(CT,label) selection, re-weighting) → **train** (weighted
//! SplitNN on the coreset, executed through PJRT-compiled XLA artifacts).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod coreset;
pub mod crypto;
pub mod data;
pub mod error;
pub mod ml;
pub mod net;
pub mod parties;
pub mod psi;
pub mod runtime;
pub mod splitnn;
pub mod util;

pub use error::{Error, Result};
