//! Adam optimizer (Kingma & Ba) — the paper's §5.1 choice for all tasks.
//!
//! Runs in Rust (L3): parameter updates are elementwise and tiny next to
//! the matmuls, and keeping them here avoids one XLA artifact per
//! parameter shape. One `Adam` instance tracks one parameter tensor.

/// Adam state for a single flat parameter tensor.
#[derive(Clone, Debug)]
pub struct Adam {
    pub lr: f32,
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(len: usize, lr: f32) -> Self {
        Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            m: vec![0.0; len],
            v: vec![0.0; len],
            t: 0,
        }
    }

    /// One update: `param -= lr * m̂ / (√v̂ + ε)`.
    pub fn step(&mut self, param: &mut [f32], grad: &[f32]) {
        assert_eq!(param.len(), self.m.len());
        assert_eq!(grad.len(), self.m.len());
        self.t += 1;
        let b1t = 1.0 - self.beta1.powi(self.t as i32);
        let b2t = 1.0 - self.beta2.powi(self.t as i32);
        for i in 0..param.len() {
            self.m[i] = self.beta1 * self.m[i] + (1.0 - self.beta1) * grad[i];
            self.v[i] = self.beta2 * self.v[i] + (1.0 - self.beta2) * grad[i] * grad[i];
            let mh = self.m[i] / b1t;
            let vh = self.v[i] / b2t;
            param[i] -= self.lr * mh / (vh.sqrt() + self.eps);
        }
    }

    pub fn steps_taken(&self) -> u64 {
        self.t
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minimizes_quadratic() {
        // f(x) = (x-3)², ∇ = 2(x-3)
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.1);
        for _ in 0..500 {
            let g = vec![2.0 * (x[0] - 3.0)];
            opt.step(&mut x, &g);
        }
        assert!((x[0] - 3.0).abs() < 1e-2, "x={}", x[0]);
    }

    #[test]
    fn first_step_magnitude_close_to_lr() {
        // Adam's bias correction makes step 1 ≈ lr × sign(grad).
        let mut x = vec![0.0f32];
        let mut opt = Adam::new(1, 0.01);
        opt.step(&mut x, &[5.0]);
        assert!((x[0] + 0.01).abs() < 1e-4, "x={}", x[0]);
    }

    #[test]
    fn zero_grad_no_move() {
        let mut x = vec![1.5f32, -2.0];
        let mut opt = Adam::new(2, 0.1);
        opt.step(&mut x, &[0.0, 0.0]);
        assert_eq!(x, vec![1.5, -2.0]);
    }

    #[test]
    fn minimizes_2d_rosenbrock_ish() {
        let mut p = vec![-1.0f32, 1.5];
        let mut opt = Adam::new(2, 0.02);
        for _ in 0..4000 {
            let (x, y) = (p[0], p[1]);
            let g = vec![
                -2.0 * (1.0 - x) - 40.0 * x * (y - x * x),
                20.0 * (y - x * x),
            ];
            opt.step(&mut p, &g);
        }
        assert!((p[0] - 1.0).abs() < 0.15 && (p[1] - 1.0).abs() < 0.25, "{p:?}");
    }
}
