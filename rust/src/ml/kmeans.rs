//! K-Means (k-means++ init, Lloyd iterations) — Cluster-Coreset step 1.
//!
//! Each client clusters its local feature slice with this. The
//! distance/assign inner loop can execute through the XLA
//! `kmeans_assign_*` artifact (Pallas kernel, see `runtime::kmeans`) or
//! natively; this module is the native engine and the shared orchestration.

use crate::data::Matrix;
use crate::util::pool::Parallel;
use crate::util::rng::Rng;

/// Assignment backend: given rows and centroids, return (assign, dist).
/// `dist` is the Euclidean distance of each row to its centroid.
///
/// Takes `&self` so one backend instance can serve several party threads
/// concurrently (per-party clustering in `coreset::cluster_coreset` fans
/// out over a shared backend).
pub trait AssignBackend {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> (Vec<u32>, Vec<f32>);
}

/// Assignment kernel over the row range `lo..hi`; `c2` holds the
/// precomputed per-centroid |c|². Shared by the serial and parallel
/// backends so both produce bitwise-identical results.
fn assign_range(
    x: &Matrix,
    centroids: &Matrix,
    c2: &[f32],
    lo: usize,
    hi: usize,
) -> (Vec<u32>, Vec<f32>) {
    let k = centroids.rows();
    let mut assign = Vec::with_capacity(hi - lo);
    let mut dist = Vec::with_capacity(hi - lo);
    for r in lo..hi {
        let row = x.row(r);
        // |x-c|² = |x|² + |c|² − 2x·c.
        let x2: f32 = row.iter().map(|v| v * v).sum();
        let mut best = 0u32;
        let mut best_d = f32::INFINITY;
        for c in 0..k {
            let dot: f32 = row.iter().zip(centroids.row(c)).map(|(a, b)| a * b).sum();
            let d = x2 + c2[c] - 2.0 * dot;
            if d < best_d {
                best_d = d;
                best = c as u32;
            }
        }
        assign.push(best);
        dist.push(best_d.max(0.0).sqrt());
    }
    (assign, dist)
}

fn centroid_norms(centroids: &Matrix) -> Vec<f32> {
    (0..centroids.rows())
        .map(|c| centroids.row(c).iter().map(|v| v * v).sum())
        .collect()
}

/// Pure-Rust serial assignment (tests, and the no-artifact fallback on
/// small inputs).
pub struct NativeAssign;

impl AssignBackend for NativeAssign {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> (Vec<u32>, Vec<f32>) {
        let c2 = centroid_norms(centroids);
        assign_range(x, centroids, &c2, 0, x.rows())
    }
}

/// Parallel native assignment: rows chunked across `par` workers; runs
/// inline below the kernel work cutoff (rows × k × dims distance terms).
/// Bitwise identical to [`NativeAssign`] at any thread count.
#[derive(Clone, Copy, Debug)]
pub struct ParAssign {
    pub par: Parallel,
}

impl AssignBackend for ParAssign {
    fn assign(&self, x: &Matrix, centroids: &Matrix) -> (Vec<u32>, Vec<f32>) {
        let work = x
            .rows()
            .saturating_mul(centroids.rows())
            .saturating_mul(x.cols().max(1));
        let par = self.par.for_work(work);
        let c2 = centroid_norms(centroids);
        let mut chunks =
            par.par_chunks(x.rows(), |r| assign_range(x, centroids, &c2, r.start, r.end));
        if chunks.len() == 1 {
            return chunks.pop().unwrap();
        }
        let mut assign = Vec::with_capacity(x.rows());
        let mut dist = Vec::with_capacity(x.rows());
        for (a, d) in chunks {
            assign.extend_from_slice(&a);
            dist.extend_from_slice(&d);
        }
        (assign, dist)
    }
}

/// K-Means configuration.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub k: usize,
    pub max_iters: usize,
    /// Stop when total centroid movement drops below this.
    pub tol: f32,
    pub seed: u64,
}

impl KMeans {
    pub fn new(k: usize) -> Self {
        KMeans { k, max_iters: 50, tol: 1e-4, seed: 42 }
    }

    /// Run Lloyd's algorithm with k-means++ seeding.
    pub fn fit(&self, x: &Matrix, backend: &impl AssignBackend) -> KMeansResult {
        assert!(x.rows() > 0, "empty input");
        let k = self.k.min(x.rows());
        let mut rng = Rng::new(self.seed);
        let mut centroids = kmeanspp_init(x, k, &mut rng);
        let mut assign = vec![0u32; x.rows()];
        let mut dist = vec![0.0f32; x.rows()];
        let mut iters = 0;
        for it in 0..self.max_iters {
            iters = it + 1;
            let (a, d) = backend.assign(x, &centroids);
            assign = a;
            dist = d;
            // Update step: mean of members; empty clusters respawn on the
            // farthest point (standard fix).
            let mut sums = Matrix::zeros(k, x.cols());
            let mut counts = vec![0usize; k];
            for (r, &c) in assign.iter().enumerate() {
                counts[c as usize] += 1;
                for (s, v) in sums.row_mut(c as usize).iter_mut().zip(x.row(r)) {
                    *s += v;
                }
            }
            let mut movement = 0.0f32;
            for c in 0..k {
                if counts[c] == 0 {
                    let far = crate::util::stats::argmax_f32(&dist);
                    sums.row_mut(c).copy_from_slice(x.row(far));
                    counts[c] = 1;
                }
                let inv = 1.0 / counts[c] as f32;
                for (j, s) in sums.row_mut(c).iter_mut().enumerate() {
                    *s *= inv;
                    movement += (*s - centroids.get(c, j)).abs();
                }
            }
            centroids = sums;
            if movement < self.tol {
                break;
            }
        }
        // Final assignment against the converged centroids.
        let (a, d) = backend.assign(x, &centroids);
        assign = a;
        dist = d;
        let _ = iters;
        KMeansResult { centroids, assign, dist, k }
    }
}

/// k-means++ seeding: probability ∝ squared distance to nearest center.
fn kmeanspp_init(x: &Matrix, k: usize, rng: &mut Rng) -> Matrix {
    let n = x.rows();
    let mut centroids = Matrix::zeros(k, x.cols());
    let first = rng.below_usize(n);
    centroids.row_mut(0).copy_from_slice(x.row(first));
    let mut d2 = vec![f32::INFINITY; n];
    for c in 1..k {
        // Update d² against the newest center.
        let new_c = centroids.row(c - 1).to_vec();
        for r in 0..n {
            let d: f32 = x
                .row(r)
                .iter()
                .zip(&new_c)
                .map(|(a, b)| (a - b) * (a - b))
                .sum();
            d2[r] = d2[r].min(d);
        }
        let total: f64 = d2.iter().map(|&v| v as f64).sum();
        let pick = if total <= 0.0 {
            rng.below_usize(n)
        } else {
            let mut t = rng.f64() * total;
            let mut idx = n - 1;
            for (r, &v) in d2.iter().enumerate() {
                t -= v as f64;
                if t <= 0.0 {
                    idx = r;
                    break;
                }
            }
            idx
        };
        centroids.row_mut(c).copy_from_slice(x.row(pick));
    }
    centroids
}

/// Fit result.
#[derive(Clone, Debug)]
pub struct KMeansResult {
    pub centroids: Matrix,
    /// Cluster index per row.
    pub assign: Vec<u32>,
    /// Euclidean distance of each row to its centroid.
    pub dist: Vec<f32>,
    pub k: usize,
}

impl KMeansResult {
    /// Sum of squared distances (inertia).
    pub fn inertia(&self) -> f64 {
        self.dist.iter().map(|&d| (d as f64) * (d as f64)).sum()
    }

    /// Members of cluster c.
    pub fn members(&self, c: u32) -> Vec<usize> {
        self.assign
            .iter()
            .enumerate()
            .filter(|(_, &a)| a == c)
            .map(|(i, _)| i)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn recovers_separated_blobs() {
        let mut rng = Rng::new(1);
        let ds = synth::blobs("t", 300, 4, 3, 1, 8.0, 0.3, &mut rng);
        let r = KMeans::new(3).fit(&ds.x, &NativeAssign);
        // Every cluster should be label-pure for well-separated blobs.
        for c in 0..3u32 {
            let mem = r.members(c);
            assert!(!mem.is_empty());
            let first = ds.y[mem[0]];
            let pure = mem.iter().all(|&i| ds.y[i] == first);
            assert!(pure, "cluster {c} mixes labels");
        }
    }

    #[test]
    fn inertia_decreases_with_k() {
        let mut rng = Rng::new(2);
        let ds = synth::blobs("t", 400, 5, 2, 4, 3.0, 1.0, &mut rng);
        let i2 = KMeans::new(2).fit(&ds.x, &NativeAssign).inertia();
        let i8 = KMeans::new(8).fit(&ds.x, &NativeAssign).inertia();
        assert!(i8 < i2, "inertia k=8 {i8} < k=2 {i2}");
    }

    #[test]
    fn k_capped_at_n() {
        let mut rng = Rng::new(3);
        let ds = synth::blobs("t", 5, 3, 2, 1, 4.0, 0.5, &mut rng);
        let r = KMeans::new(10).fit(&ds.x, &NativeAssign);
        assert_eq!(r.k, 5);
        assert_eq!(r.centroids.rows(), 5);
    }

    #[test]
    fn assignments_minimize_distance() {
        let mut rng = Rng::new(4);
        let ds = synth::blobs("t", 100, 3, 2, 2, 3.0, 1.0, &mut rng);
        let r = KMeans::new(4).fit(&ds.x, &NativeAssign);
        for i in 0..ds.n() {
            let assigned = r.assign[i] as usize;
            for c in 0..r.k {
                let d: f32 = ds.x
                    .row(i)
                    .iter()
                    .zip(r.centroids.row(c))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                let da: f32 = ds.x
                    .row(i)
                    .iter()
                    .zip(r.centroids.row(assigned))
                    .map(|(a, b)| (a - b) * (a - b))
                    .sum();
                assert!(da <= d + 1e-4, "row {i}: {assigned} not nearest");
            }
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Rng::new(5);
        let ds = synth::blobs("t", 120, 4, 2, 2, 3.0, 1.0, &mut rng);
        let a = KMeans::new(3).fit(&ds.x, &NativeAssign);
        let b = KMeans::new(3).fit(&ds.x, &NativeAssign);
        assert_eq!(a.assign, b.assign);
    }

    #[test]
    fn par_assign_bitwise_matches_serial() {
        // 4000 rows × 6 centroids × 16 dims = 384k work units > PAR_MIN_WORK,
        // so the chunked path genuinely runs.
        let mut rng = Rng::new(6);
        let ds = synth::blobs("t", 4000, 16, 3, 2, 4.0, 1.0, &mut rng);
        let centroids = ds.x.select_rows(&rng.sample_indices(ds.n(), 6));
        let (sa, sd) = NativeAssign.assign(&ds.x, &centroids);
        for t in [1usize, 2, 4, 8] {
            let backend = ParAssign { par: Parallel::new(t) };
            let (pa, pd) = backend.assign(&ds.x, &centroids);
            assert_eq!(pa, sa, "threads={t}");
            assert_eq!(pd, sd, "threads={t}");
        }
    }

    #[test]
    fn fit_with_par_backend_matches_serial_fit() {
        let mut rng = Rng::new(7);
        let ds = synth::blobs("t", 600, 8, 2, 2, 3.0, 1.0, &mut rng);
        let serial = KMeans::new(4).fit(&ds.x, &NativeAssign);
        let par = KMeans::new(4).fit(&ds.x, &ParAssign { par: Parallel::new(4) });
        assert_eq!(serial.assign, par.assign);
        assert_eq!(serial.dist, par.dist);
    }
}
