//! Machine-learning substrate: K-Means, Adam, KNN, and evaluation metrics.
//!
//! The SplitNN model phases themselves live in [`crate::splitnn`] (they
//! execute through XLA artifacts with a native parity fallback); this
//! module holds everything else the paper's pipeline needs.

pub mod adam;
pub mod kmeans;
pub mod knn;
pub mod metrics;

pub use adam::Adam;
pub use kmeans::{KMeans, KMeansResult};
