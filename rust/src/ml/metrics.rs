//! Evaluation metrics: accuracy (classification) and MSE (regression) —
//! the two quantities Table 2 reports.

use crate::data::Matrix;
use crate::util::stats::argmax_f32;

/// Classification accuracy from logits (rows = samples).
pub fn accuracy_from_logits(logits: &Matrix, y: &[f32]) -> f64 {
    assert_eq!(logits.rows(), y.len());
    let mut correct = 0usize;
    for (r, &label) in y.iter().enumerate() {
        if argmax_f32(logits.row(r)) == label as usize {
            correct += 1;
        }
    }
    correct as f64 / y.len().max(1) as f64
}

/// Binary accuracy from scalar logits (sigmoid threshold at 0).
pub fn binary_accuracy_from_scores(scores: &[f32], y: &[f32]) -> f64 {
    assert_eq!(scores.len(), y.len());
    let correct = scores
        .iter()
        .zip(y)
        .filter(|(&s, &label)| (s > 0.0) == (label > 0.5))
        .count();
    correct as f64 / y.len().max(1) as f64
}

/// Mean squared error.
pub fn mse(pred: &[f32], y: &[f32]) -> f64 {
    assert_eq!(pred.len(), y.len());
    if pred.is_empty() {
        return 0.0;
    }
    pred.iter()
        .zip(y)
        .map(|(&p, &t)| ((p - t) as f64).powi(2))
        .sum::<f64>()
        / pred.len() as f64
}

/// Per-class predictions from votes (KNN): majority with weight ties → min
/// class index.
pub fn majority_vote(votes: &[(usize, f32)], n_classes: usize) -> usize {
    let mut tally = vec![0.0f32; n_classes];
    for &(c, w) in votes {
        tally[c] += w;
    }
    argmax_f32(&tally)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn accuracy_counts_argmax() {
        let logits = Matrix::from_vec(3, 2, vec![2.0, 1.0, 0.0, 3.0, 1.0, 1.5]).unwrap();
        let y = vec![0.0, 1.0, 0.0];
        assert!((accuracy_from_logits(&logits, &y) - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn binary_accuracy_thresholds_at_zero() {
        let s = vec![-1.0, 0.5, 3.0, -0.2];
        let y = vec![0.0, 1.0, 1.0, 1.0];
        assert!((binary_accuracy_from_scores(&s, &y) - 0.75).abs() < 1e-12);
    }

    #[test]
    fn mse_known() {
        assert!((mse(&[1.0, 2.0], &[0.0, 4.0]) - 2.5).abs() < 1e-12);
        assert_eq!(mse(&[], &[]), 0.0);
    }

    #[test]
    fn vote_weighted() {
        // class 1 has more weight despite fewer votes
        let votes = [(0usize, 1.0f32), (0, 1.0), (1, 3.0)];
        assert_eq!(majority_vote(&votes, 2), 1);
    }
}
