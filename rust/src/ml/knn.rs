//! Weighted K-nearest-neighbors over the coreset (Table 2's KNN column).
//!
//! VFL-KNN: each client computes *squared* distances between the query's
//! local feature slice and its slice of the reference (coreset) rows; the
//! aggregator sums the per-client squared distances to get global
//! distances. Coreset sample weights enter the vote (paper §4.2 step 5:
//! "coreset-based similarity calculations").
//!
//! The pairwise-distance hot-spot can run through the `pairwise_*` XLA
//! artifact (Pallas kernel) or natively; both produce squared distances.
//! The native kernel chunks query rows across a [`Parallel`] worker set
//! ([`ParPairwise`]) with bitwise-identical results at any thread count.

use crate::data::Matrix;
use crate::ml::metrics::majority_vote;
use crate::util::pool::{concat_chunks, Parallel};

/// Pairwise squared-distance backend.
///
/// `&self` so one backend can serve concurrent callers (mirrors
/// [`crate::ml::kmeans::AssignBackend`]).
pub trait PairwiseBackend {
    /// (|Q| × |R|) squared Euclidean distances.
    fn pairwise_sq(&self, q: &Matrix, r: &Matrix) -> Matrix;
}

/// Shared kernel: query rows `lo..hi` against every reference row, with
/// `r2` the precomputed per-reference |r|². Flat row-major output.
fn pairwise_rows(q: &Matrix, r: &Matrix, r2: &[f32], lo: usize, hi: usize) -> Vec<f32> {
    let mut out = Vec::with_capacity((hi - lo) * r.rows());
    for qi in lo..hi {
        let qrow = q.row(qi);
        let q2: f32 = qrow.iter().map(|v| v * v).sum();
        for ri in 0..r.rows() {
            let dot: f32 = qrow.iter().zip(r.row(ri)).map(|(a, b)| a * b).sum();
            out.push((q2 + r2[ri] - 2.0 * dot).max(0.0));
        }
    }
    out
}

fn pairwise_impl(q: &Matrix, r: &Matrix, par: Parallel) -> Matrix {
    assert_eq!(q.cols(), r.cols());
    let r2: Vec<f32> = (0..r.rows())
        .map(|i| r.row(i).iter().map(|v| v * v).sum())
        .collect();
    let work = q
        .rows()
        .saturating_mul(r.rows())
        .saturating_mul(q.cols().max(1));
    let par = par.for_work(work);
    let chunks = par.par_chunks(q.rows(), |range| {
        pairwise_rows(q, r, &r2, range.start, range.end)
    });
    let data = concat_chunks(chunks, q.rows() * r.rows());
    Matrix::from_vec(q.rows(), r.rows(), data).expect("pairwise shape")
}

/// Pure-Rust serial pairwise distances.
pub struct NativePairwise;

impl PairwiseBackend for NativePairwise {
    fn pairwise_sq(&self, q: &Matrix, r: &Matrix) -> Matrix {
        pairwise_impl(q, r, Parallel::serial())
    }
}

/// Parallel native pairwise distances (query rows chunked over `par`).
#[derive(Clone, Copy, Debug)]
pub struct ParPairwise {
    pub par: Parallel,
}

impl PairwiseBackend for ParPairwise {
    fn pairwise_sq(&self, q: &Matrix, r: &Matrix) -> Matrix {
        pairwise_impl(q, r, self.par)
    }
}

/// KNN classifier state: reference rows + labels + per-sample weights.
pub struct Knn {
    pub k: usize,
    pub n_classes: usize,
}

impl Knn {
    pub fn new(k: usize, n_classes: usize) -> Self {
        Knn { k, n_classes }
    }

    /// Classify each query row given a precomputed global squared-distance
    /// matrix (|Q| × |R|), reference labels, and reference weights.
    pub fn classify_from_dists(
        &self,
        dists: &Matrix,
        ref_y: &[f32],
        ref_w: &[f32],
    ) -> Vec<usize> {
        assert_eq!(dists.cols(), ref_y.len());
        assert_eq!(ref_y.len(), ref_w.len());
        let k = self.k.min(ref_y.len());
        let mut preds = Vec::with_capacity(dists.rows());
        let mut idx: Vec<usize> = (0..ref_y.len()).collect();
        for q in 0..dists.rows() {
            let row = dists.row(q);
            // Partial selection of the k nearest.
            idx.sort_by(|&a, &b| row[a].partial_cmp(&row[b]).unwrap());
            let votes: Vec<(usize, f32)> = idx[..k]
                .iter()
                .map(|&i| (ref_y[i] as usize, ref_w[i].max(1e-6)))
                .collect();
            preds.push(majority_vote(&votes, self.n_classes));
            // restore for next row (sort handles it; idx stays a permutation)
        }
        preds
    }

    /// End-to-end helper with a backend: distances then vote.
    pub fn classify(
        &self,
        backend: &impl PairwiseBackend,
        queries: &Matrix,
        refs: &Matrix,
        ref_y: &[f32],
        ref_w: &[f32],
    ) -> Vec<usize> {
        let d = backend.pairwise_sq(queries, refs);
        self.classify_from_dists(&d, ref_y, ref_w)
    }
}

/// Sum per-client squared-distance matrices into global distances
/// (the aggregator's VFL-KNN step).
pub fn sum_client_dists(parts: &[Matrix]) -> Matrix {
    assert!(!parts.is_empty());
    let mut total = parts[0].clone();
    for p in &parts[1..] {
        total = total.zip(p, |a, b| a + b).expect("same shape");
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::data::VerticalPartition;
    use crate::util::rng::Rng;

    #[test]
    fn classifies_separated_blobs() {
        let mut rng = Rng::new(1);
        let ds = synth::blobs("t", 300, 5, 2, 1, 8.0, 0.4, &mut rng);
        let (tr, te) = ds.split(0.7, &mut rng);
        let knn = Knn::new(5, 2);
        let w = vec![1.0; tr.n()];
        let preds = knn.classify(&NativePairwise, &te.x, &tr.x, &tr.y, &w);
        let acc = preds
            .iter()
            .zip(&te.y)
            .filter(|(&p, &y)| p == y as usize)
            .count() as f64
            / te.n() as f64;
        assert!(acc > 0.95, "acc {acc}");
    }

    #[test]
    fn client_distance_sum_equals_global() {
        let mut rng = Rng::new(2);
        let ds = synth::blobs("t", 40, 9, 2, 1, 4.0, 1.0, &mut rng);
        let part = VerticalPartition::even(9, 3);
        let q = ds.subset(&(0..10).collect::<Vec<_>>());
        let r = ds.subset(&(10..40).collect::<Vec<_>>());
        let nb = NativePairwise;
        let global = nb.pairwise_sq(&q.x, &r.x);
        let parts: Vec<Matrix> = (0..3)
            .map(|c| nb.pairwise_sq(&part.slice(&q.x, c), &part.slice(&r.x, c)))
            .collect();
        let summed = sum_client_dists(&parts);
        assert!(global.max_abs_diff(&summed) < 1e-3);
    }

    #[test]
    fn weights_can_flip_votes() {
        // 1 near neighbor of class 1 with huge weight vs 2 of class 0.
        let refs = Matrix::from_vec(3, 1, vec![0.0, 0.1, 0.2]).unwrap();
        let q = Matrix::from_vec(1, 1, vec![0.05]).unwrap();
        let y = vec![0.0, 1.0, 0.0];
        let knn = Knn::new(3, 2);
        let unweighted = knn.classify(&NativePairwise, &q, &refs, &y, &[1.0, 1.0, 1.0]);
        assert_eq!(unweighted, vec![0]);
        let weighted = knn.classify(&NativePairwise, &q, &refs, &y, &[1.0, 5.0, 1.0]);
        assert_eq!(weighted, vec![1]);
    }

    #[test]
    fn par_pairwise_bitwise_matches_serial() {
        // 600 × 500 × 8 = 2.4M work units — well above the inline cutoff.
        let mut rng = Rng::new(3);
        let q = Matrix::from_fn(600, 8, |_, _| rng.gaussian_f32());
        let r = Matrix::from_fn(500, 8, |_, _| rng.gaussian_f32());
        let serial = NativePairwise.pairwise_sq(&q, &r);
        for t in [1usize, 2, 4, 8] {
            let par = ParPairwise { par: Parallel::new(t) }.pairwise_sq(&q, &r);
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn k_capped_by_refs() {
        let refs = Matrix::from_vec(2, 1, vec![0.0, 1.0]).unwrap();
        let q = Matrix::from_vec(1, 1, vec![0.1]).unwrap();
        let preds = Knn::new(10, 2).classify(
            &NativePairwise,
            &q,
            &refs,
            &[0.0, 1.0],
            &[1.0, 1.0],
        );
        assert_eq!(preds.len(), 1);
    }
}
