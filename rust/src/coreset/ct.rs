//! Steps 3–4 of Cluster-Coreset: cluster tuples and representative
//! selection (label-owner side).
//!
//! For each aligned sample i the label owner assembles
//! `CT_i = (c_i^1, …, c_i^M)` from the clients' messages, groups samples by
//! (CT value, label), and keeps from each group the sample with minimal
//! aggregated distance Σ_m ed_i^m. The coreset weight of a selected sample
//! is the sum of its local weights, w_i = Σ_m w_i^m (step 5).
//!
//! Regression has no label classes; each CT group yields one
//! representative (documented deviation — the paper only defines the split
//! "based on their labels" for classification).

use std::collections::HashMap;

/// Per-client per-sample message content after decryption (step 3).
#[derive(Clone, Debug)]
pub struct ClientCtData {
    /// Local weights w_i^m.
    pub weights: Vec<f32>,
    /// Local cluster index c_i^m.
    pub clusters: Vec<u32>,
    /// Local centroid distance ed_i^m.
    pub dists: Vec<f32>,
}

/// Label key for grouping: class index, or a single bucket for regression.
fn label_key(y: f32, is_classification: bool) -> i64 {
    if is_classification {
        y as i64
    } else {
        0
    }
}

/// Selection output: positions (into the aligned order) + summed weights.
#[derive(Clone, Debug)]
pub struct Selection {
    /// Indices of selected samples, ascending.
    pub indices: Vec<usize>,
    /// w_i = Σ_m w_i^m for each selected sample (parallel to `indices`).
    pub weights: Vec<f32>,
    /// Number of distinct CT values observed.
    pub distinct_cts: usize,
}

/// Run steps 4–5 at the label owner.
///
/// `clients[m]` carries client m's weights/clusters/distances for the same
/// aligned sample order; `y` are the label owner's labels.
pub fn select(clients: &[ClientCtData], y: &[f32], is_classification: bool) -> Selection {
    assert!(!clients.is_empty());
    let n = y.len();
    for c in clients {
        assert_eq!(c.weights.len(), n);
        assert_eq!(c.clusters.len(), n);
        assert_eq!(c.dists.len(), n);
    }
    // Group by (CT, label); track the argmin of aggregated distance.
    // Key: (label, CT as Vec<u32>). Value: (best index, best agg dist).
    let mut groups: HashMap<(i64, Vec<u32>), (usize, f32)> = HashMap::new();
    let mut distinct: std::collections::HashSet<Vec<u32>> = std::collections::HashSet::new();
    for i in 0..n {
        let ct: Vec<u32> = clients.iter().map(|c| c.clusters[i]).collect();
        let agg: f32 = clients.iter().map(|c| c.dists[i]).sum();
        distinct.insert(ct.clone());
        let key = (label_key(y[i], is_classification), ct);
        groups
            .entry(key)
            .and_modify(|best| {
                if agg < best.1 {
                    *best = (i, agg);
                }
            })
            .or_insert((i, agg));
    }
    let mut indices: Vec<usize> = groups.values().map(|&(i, _)| i).collect();
    indices.sort_unstable();
    let weights = indices
        .iter()
        .map(|&i| clients.iter().map(|c| c.weights[i]).sum())
        .collect();
    Selection { indices, weights, distinct_cts: distinct.len() }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn client(clusters: Vec<u32>, dists: Vec<f32>) -> ClientCtData {
        let weights = vec![0.5; clusters.len()];
        ClientCtData { weights, clusters, dists }
    }

    #[test]
    fn identical_cts_same_label_collapse_to_argmin() {
        // Samples 0,1,2 share CT (0,0); sample 1 has min aggregated dist.
        let c1 = client(vec![0, 0, 0], vec![3.0, 1.0, 2.0]);
        let c2 = client(vec![0, 0, 0], vec![3.0, 0.5, 2.0]);
        let y = vec![1.0, 1.0, 1.0];
        let s = select(&[c1, c2], &y, true);
        assert_eq!(s.indices, vec![1]);
        assert_eq!(s.weights, vec![1.0]); // 0.5 + 0.5
        assert_eq!(s.distinct_cts, 1);
    }

    #[test]
    fn label_split_keeps_one_per_class() {
        // Same CT but two labels → two representatives.
        let c1 = client(vec![0, 0, 0, 0], vec![1.0, 2.0, 3.0, 0.5]);
        let y = vec![0.0, 0.0, 1.0, 1.0];
        let s = select(&[c1], &y, true);
        assert_eq!(s.indices, vec![0, 3]); // argmin within each class
    }

    #[test]
    fn different_cts_all_kept() {
        let c1 = client(vec![0, 1, 2], vec![1.0, 1.0, 1.0]);
        let c2 = client(vec![0, 0, 0], vec![1.0, 1.0, 1.0]);
        let y = vec![0.0, 0.0, 0.0];
        let s = select(&[c1, c2], &y, true);
        assert_eq!(s.indices, vec![0, 1, 2]);
        assert_eq!(s.distinct_cts, 3);
    }

    #[test]
    fn regression_ignores_label_values() {
        // Identical CTs, distinct continuous labels → ONE representative.
        let c1 = client(vec![0, 0], vec![2.0, 1.0]);
        let y = vec![10.5, -3.25];
        let s = select(&[c1], &y, false);
        assert_eq!(s.indices, vec![1]);
    }

    #[test]
    fn coreset_never_larger_than_input() {
        let mut rng = crate::util::rng::Rng::new(7);
        let n = 200;
        let mk = |rng: &mut crate::util::rng::Rng| ClientCtData {
            weights: (0..n).map(|_| rng.f32()).collect(),
            clusters: (0..n).map(|_| rng.below(4) as u32).collect(),
            dists: (0..n).map(|_| rng.f32() * 3.0).collect(),
        };
        let clients = vec![mk(&mut rng), mk(&mut rng), mk(&mut rng)];
        let y: Vec<f32> = (0..n).map(|i| (i % 2) as f32).collect();
        let s = select(&clients, &y, true);
        assert!(s.indices.len() <= n);
        // At most distinct_cts × classes representatives.
        assert!(s.indices.len() <= s.distinct_cts * 2);
        // Indices are unique + sorted.
        let mut dedup = s.indices.clone();
        dedup.dedup();
        assert_eq!(dedup, s.indices);
    }

    #[test]
    fn selected_weights_are_sums_of_local_weights() {
        let c1 = ClientCtData {
            weights: vec![0.25, 1.0],
            clusters: vec![0, 1],
            dists: vec![1.0, 1.0],
        };
        let c2 = ClientCtData {
            weights: vec![0.75, 0.5],
            clusters: vec![0, 0],
            dists: vec![1.0, 1.0],
        };
        let s = select(&[c1, c2], &[0.0, 0.0], true);
        assert_eq!(s.indices, vec![0, 1]);
        assert_eq!(s.weights, vec![1.0, 1.5]);
    }
}
