//! Coreset construction (paper §4.2).
//!
//! * [`weights`] — step 2: rank-based local sample weights.
//! * [`ct`] — steps 3–4: cluster tuples, per-(CT, label) representative
//!   selection, weight summation.
//! * [`cluster_coreset`] — the full five-step Cluster-Coreset protocol
//!   across clients / aggregator / label owner with HE-enveloped messages.
//! * [`vcoreset`] — the V-coreset baseline (leverage-score sampling for
//!   regression, sensitivity sampling for clustering/classification).

pub mod cluster_coreset;
pub mod ct;
pub mod vcoreset;
pub mod weights;

pub use cluster_coreset::{ClusterCoresetConfig, CoresetResult};
