//! Step 2 of Cluster-Coreset: local per-sample weights.
//!
//! Paper formula for sample i in cluster c on client m:
//!
//! ```text
//!   w_i^m = (1 / |S_m^c|) · pos(ed_i^m, DeSort({ed_j^m : j ∈ S_m^c}))
//! ```
//!
//! `DeSort` sorts the cluster's members by distance *descending*; `pos` is
//! the 1-based position. The farthest member gets weight 1/|S|, the member
//! nearest the centroid gets |S|/|S| = 1 — "those closer to the centroids
//! are more representative".

/// Compute local weights from cluster assignments + centroid distances.
/// Returns one weight per sample, in input order.
pub fn local_weights(assign: &[u32], dist: &[f32], k: usize) -> Vec<f32> {
    assert_eq!(assign.len(), dist.len());
    let n = assign.len();
    let mut weights = vec![0.0f32; n];
    // Bucket samples per cluster.
    let mut clusters: Vec<Vec<usize>> = vec![Vec::new(); k];
    for (i, &c) in assign.iter().enumerate() {
        clusters[c as usize].push(i);
    }
    for members in clusters {
        if members.is_empty() {
            continue;
        }
        let s = members.len() as f32;
        // DeSort by distance descending; ties broken by index so the
        // ranking is deterministic.
        let mut sorted = members.clone();
        sorted.sort_by(|&a, &b| {
            dist[b]
                .partial_cmp(&dist[a])
                .unwrap()
                .then(a.cmp(&b))
        });
        for (pos0, &i) in sorted.iter().enumerate() {
            let pos = (pos0 + 1) as f32; // 1-based
            weights[i] = pos / s;
        }
    }
    weights
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nearest_gets_weight_one_farthest_gets_1_over_s() {
        // One cluster of 4, distances 4 > 3 > 2 > 1.
        let assign = [0u32, 0, 0, 0];
        let dist = [4.0f32, 3.0, 2.0, 1.0];
        let w = local_weights(&assign, &dist, 1);
        assert_eq!(w, vec![0.25, 0.5, 0.75, 1.0]);
    }

    #[test]
    fn weights_computed_per_cluster() {
        // Cluster 0: {0,1}; cluster 1: {2}.
        let assign = [0u32, 0, 1];
        let dist = [1.0f32, 2.0, 5.0];
        let w = local_weights(&assign, &dist, 2);
        assert_eq!(w[0], 1.0); // nearest of two
        assert_eq!(w[1], 0.5); // farthest of two
        assert_eq!(w[2], 1.0); // singleton: pos 1 / size 1
    }

    #[test]
    fn all_weights_in_unit_interval() {
        let mut rng = crate::util::rng::Rng::new(1);
        let n = 500;
        let k = 7;
        let assign: Vec<u32> = (0..n).map(|_| rng.below(k as u64) as u32).collect();
        let dist: Vec<f32> = (0..n).map(|_| rng.f32() * 10.0).collect();
        let w = local_weights(&assign, &dist, k);
        for (i, &wi) in w.iter().enumerate() {
            assert!(wi > 0.0 && wi <= 1.0, "w[{i}] = {wi}");
        }
    }

    #[test]
    fn ties_are_deterministic() {
        let assign = [0u32, 0, 0];
        let dist = [2.0f32, 2.0, 2.0];
        let a = local_weights(&assign, &dist, 1);
        let b = local_weights(&assign, &dist, 1);
        assert_eq!(a, b);
        // Tie ranks are a permutation of {1/3, 2/3, 1}.
        let mut sorted = a.clone();
        sorted.sort_by(|x, y| x.partial_cmp(y).unwrap());
        assert_eq!(sorted, vec![1.0 / 3.0, 2.0 / 3.0, 1.0]);
    }

    #[test]
    fn empty_clusters_ok() {
        let assign = [2u32, 2];
        let dist = [1.0f32, 2.0];
        let w = local_weights(&assign, &dist, 5);
        assert_eq!(w.len(), 2);
        assert!(w.iter().all(|&x| x > 0.0));
    }

    /// Random (assign, dist, k) instance for the property tests.
    fn gen_case(r: &mut crate::util::rng::Rng) -> (Vec<u32>, Vec<f32>, usize) {
        let k = 1 + r.below_usize(8);
        let n = 1 + r.below_usize(200);
        let assign: Vec<u32> = (0..n).map(|_| r.below(k as u64) as u32).collect();
        let dist: Vec<f32> = (0..n).map(|_| r.f32() * 10.0).collect();
        (assign, dist, k)
    }

    #[test]
    fn prop_weights_positive_and_at_most_one() {
        crate::util::check::forall_default(gen_case, |(assign, dist, k)| {
            let w = local_weights(assign, dist, *k);
            w.len() == assign.len() && w.iter().all(|&wi| wi > 0.0 && wi <= 1.0)
        });
    }

    #[test]
    fn prop_cluster_weight_mass_matches_cluster_size() {
        // Ranks 1..s scaled by 1/s sum to (s+1)/2 — the per-cluster mass
        // depends only on |S_m^c|, never on the distances.
        crate::util::check::forall_default(gen_case, |(assign, dist, k)| {
            let w = local_weights(assign, dist, *k);
            (0..*k as u32).all(|c| {
                let members: Vec<usize> =
                    (0..assign.len()).filter(|&i| assign[i] == c).collect();
                let s = members.len();
                let mass: f64 = members.iter().map(|&i| w[i] as f64).sum();
                let want = s as f64 * (s as f64 + 1.0) / 2.0 / s.max(1) as f64;
                (mass - want).abs() < 1e-3 * want.max(1.0)
            })
        });
    }

    #[test]
    fn prop_weights_distance_monotone_within_cluster() {
        // Strictly closer to the centroid ⇒ strictly more representative.
        crate::util::check::forall_default(gen_case, |(assign, dist, k)| {
            let w = local_weights(assign, dist, *k);
            for i in 0..assign.len() {
                for j in 0..assign.len() {
                    if assign[i] == assign[j] && dist[i] < dist[j] && w[i] <= w[j] {
                        return false;
                    }
                }
            }
            true
        });
    }
}
