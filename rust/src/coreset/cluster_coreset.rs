//! The full five-step Cluster-Coreset protocol (paper §4.2, Fig. 3),
//! executed across clients, aggregation server and label owner with every
//! message HE-enveloped and exchanged over the [`Transport`] (wrap it in
//! [`crate::net::MeteredTransport`] and every byte is charged on delivery).
//!
//!   1. each client K-Means-clusters its local feature slice;
//!   2. each client computes rank-based local weights;
//!   3. clients send (weight, cluster, distance) per sample to the label
//!      owner *via the aggregation server*, sealed under HE — the server
//!      routes ciphertext it cannot open;
//!   4. the label owner groups by (CT, label) and selects the minimal-
//!      aggregated-distance representative per group;
//!   5. selected indicators go back to all clients (HE again); weights are
//!      the per-client sums.

use crate::data::Matrix;
use crate::error::Result;
use crate::ml::kmeans::{AssignBackend, KMeans};
use crate::net::msg::{self, CtMessage, HybridEnvelope};
use crate::net::{Endpoint, PartyId, Transport};
use crate::parties::{recv_sealed_ct, send_sealed_ct, AggregatorNode};
use crate::psi::common::HeContext;
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::ct::{self, ClientCtData};
use super::weights::local_weights;

/// Cluster-Coreset parameters.
#[derive(Clone, Debug)]
pub struct ClusterCoresetConfig {
    /// Clusters per client (paper sweeps 2..32 in Fig. 4/5).
    pub clusters_per_client: usize,
    /// Apply the rank-based re-weighting (Fig. 4/5 ablation switch).
    /// When false, selected samples get weight 1.
    pub reweight: bool,
    pub kmeans_iters: usize,
    pub seed: u64,
    /// Worker threads for the per-party clustering fan-out (0 = all
    /// logical cores). The fan-out is order-preserving and each party's
    /// fit is independent, so the result is identical at any setting.
    /// NB: `coordinator::run_pipeline` overrides this from its single
    /// `PipelineConfig::threads` knob; set it directly only when calling
    /// `run` yourself.
    pub threads: usize,
}

impl Default for ClusterCoresetConfig {
    fn default() -> Self {
        ClusterCoresetConfig {
            clusters_per_client: 8,
            reweight: true,
            kmeans_iters: 25,
            seed: 99,
            threads: 0,
        }
    }
}

/// Output of the protocol.
#[derive(Clone, Debug)]
pub struct CoresetResult {
    /// Positions of coreset samples in the aligned order, ascending.
    pub indices: Vec<usize>,
    /// Training weights (summed local weights, or 1.0 if !reweight).
    pub weights: Vec<f32>,
    pub distinct_cts: usize,
    pub wall_s: f64,
    /// Simulated communication time of the protocol's messages.
    pub sim_s: f64,
    pub bytes: u64,
}

impl CoresetResult {
    /// Fraction of training data removed (the paper reports up to 98.4%).
    pub fn reduction(&self, n_aligned: usize) -> f64 {
        1.0 - self.indices.len() as f64 / n_aligned.max(1) as f64
    }
}

/// Run Cluster-Coreset over aligned client slices.
///
/// `slices[m]`: client m's aligned feature matrix; `y`: label owner's
/// aligned labels; `is_classification` controls the per-label split.
pub fn run(
    slices: &[Matrix],
    y: &[f32],
    is_classification: bool,
    cfg: &ClusterCoresetConfig,
    backend: &(impl AssignBackend + Sync),
    net: &dyn Transport,
    he: &HeContext,
) -> Result<CoresetResult> {
    let sw = Stopwatch::start();
    let mut sim_s = 0.0f64;
    let mut bytes = 0u64;
    let mut rng = Rng::new(cfg.seed ^ 0xC0E5E7);
    let n = y.len();
    let par = Parallel::auto(cfg.threads);
    let agg = AggregatorNode;
    let label_owner = Endpoint::new(net, PartyId::LabelOwner);

    // Steps 1–2, every client concurrently: cluster the local slice and
    // compute rank-based weights. Pure per-party compute — the paper's
    // clients run these on their own machines, so the fan-out also makes
    // the simulation honest about available parallelism.
    let fits: Vec<(Vec<f32>, Vec<u32>, Vec<f32>)> = par.par_map(slices, |m, x| {
        assert_eq!(x.rows(), n, "client {m} misaligned");
        let mut km = KMeans::new(cfg.clusters_per_client);
        km.max_iters = cfg.kmeans_iters;
        km.seed = cfg.seed ^ (m as u64) << 8;
        let fit = km.fit(x, backend);
        let w = local_weights(&fit.assign, &fit.dist, fit.k);
        (w, fit.assign, fit.dist)
    });

    // Step 3 per client, serialized: seal (w, c, ed) per sample; the
    // envelope travels client → aggregator → label owner, and the label
    // owner decodes what arrived. The shared RNG (envelope nonces) and the
    // transport keep their exact pre-parallelization consumption order
    // here, so runs are reproducible at any thread count — the envelope's
    // Paillier batch crypto still fans out over `par` internally (the
    // randomness draws stay serial; see `HybridEnvelope::seal`).
    let mut client_data = Vec::with_capacity(slices.len());
    for (m, (w, clusters, dists)) in fits.into_iter().enumerate() {
        let ct_msg = CtMessage { client: m as u32, weights: w, clusters, dists };
        let (sim, wire_bytes) =
            send_sealed_ct(net, m as u32, &mut rng, &he.pk, &ct_msg, "coreset/ct", par)?;
        sim_s += sim;
        // The aggregator forwards the same ciphertext, so the second hop
        // carries the same byte count.
        bytes += 2 * wire_bytes;
        sim_s +=
            agg.route(net, PartyId::Client(m as u32), PartyId::LabelOwner, "coreset/ct")?;
        let decoded = recv_sealed_ct(net, he, "coreset/ct", par)?;
        client_data.push(ClientCtData {
            weights: decoded.weights,
            clusters: decoded.clusters,
            dists: decoded.dists,
        });
    }

    // Step 4: label owner selects representatives.
    let selection = ct::select(&client_data, y, is_classification);

    // Step 5: broadcast selected indicators (sealed) to all clients via
    // the aggregator, each of whom opens its delivery.
    let sel_u64: Vec<u64> = selection.indices.iter().map(|&i| i as u64).collect();
    let payload = msg::encode_index_list(&sel_u64);
    let sealed = HybridEnvelope::seal(&mut rng, &he.pk, &payload, par)?;
    let wire = sealed.encode();
    bytes += wire.len() as u64 * (1 + slices.len() as u64);
    sim_s += label_owner.send(PartyId::Aggregator, "coreset/sel", wire)?;
    let agg_ep = agg.endpoint(net);
    let routed = agg_ep.recv(PartyId::LabelOwner, "coreset/sel")?;
    for c in 0..slices.len() {
        sim_s += agg_ep.send(PartyId::Client(c as u32), "coreset/sel", routed.payload.clone())?;
        let delivered = Endpoint::new(net, PartyId::Client(c as u32))
            .recv(PartyId::Aggregator, "coreset/sel")?;
        let opened = HybridEnvelope::decode(&delivered.payload)?.open(he.private(), par)?;
        if msg::decode_index_list(&opened)? != sel_u64 {
            return Err(crate::Error::Psi("selection broadcast corrupted".into()));
        }
    }

    let weights = if cfg.reweight {
        selection.weights
    } else {
        vec![1.0; selection.indices.len()]
    };

    Ok(CoresetResult {
        indices: selection.indices,
        weights,
        distinct_cts: selection.distinct_cts,
        wall_s: sw.elapsed_secs(),
        sim_s,
        bytes,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{synth, VerticalPartition};
    use crate::ml::kmeans::NativeAssign;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};

    fn run_on(
        ds: &crate::data::Dataset,
        k: usize,
        reweight: bool,
    ) -> (CoresetResult, usize) {
        let part = VerticalPartition::even(ds.d(), 3);
        let slices: Vec<Matrix> = (0..3).map(|c| part.slice(&ds.x, c)).collect();
        let net = ChannelTransport::new();
        let he = HeContext::for_tests();
        let cfg = ClusterCoresetConfig {
            clusters_per_client: k,
            reweight,
            ..Default::default()
        };
        let r = run(
            &slices,
            &ds.y,
            ds.task.is_classification(),
            &cfg,
            &NativeAssign,
            &net,
            &he,
        )
        .unwrap();
        assert_eq!(net.pending(), 0, "protocol drains the wire");
        (r, ds.n())
    }

    #[test]
    fn compresses_redundant_data_hard() {
        let mut rng = crate::util::rng::Rng::new(1);
        // RI-like: 2 tight modes per class → tiny coreset.
        let ds = synth::blobs("t", 1000, 8, 2, 2, 6.0, 0.4, &mut rng);
        let (r, n) = run_on(&ds, 4, true);
        assert!(r.reduction(n) > 0.9, "reduction {}", r.reduction(n));
        assert!(!r.indices.is_empty());
    }

    #[test]
    fn coreset_grows_with_clusters_per_client() {
        let mut rng = crate::util::rng::Rng::new(2);
        let ds = synth::blobs("t", 800, 8, 2, 4, 2.5, 1.0, &mut rng);
        let (r2, _) = run_on(&ds, 2, true);
        let (r16, _) = run_on(&ds, 16, true);
        assert!(
            r16.indices.len() > r2.indices.len(),
            "{} > {}",
            r16.indices.len(),
            r2.indices.len()
        );
    }

    #[test]
    fn weights_sum_of_clients_bounded_by_m() {
        let mut rng = crate::util::rng::Rng::new(3);
        let ds = synth::blobs("t", 300, 9, 2, 2, 3.0, 1.0, &mut rng);
        let (r, _) = run_on(&ds, 4, true);
        for &w in &r.weights {
            assert!(w > 0.0 && w <= 3.0 + 1e-5, "w={w} with 3 clients");
        }
    }

    #[test]
    fn no_reweight_gives_unit_weights() {
        let mut rng = crate::util::rng::Rng::new(4);
        let ds = synth::blobs("t", 200, 6, 2, 2, 3.0, 1.0, &mut rng);
        let (r, _) = run_on(&ds, 4, false);
        assert!(r.weights.iter().all(|&w| w == 1.0));
    }

    #[test]
    fn every_class_represented() {
        let mut rng = crate::util::rng::Rng::new(5);
        let ds = synth::blobs("t", 400, 8, 4, 2, 4.0, 0.8, &mut rng);
        let (r, _) = run_on(&ds, 4, true);
        let classes: std::collections::HashSet<i64> =
            r.indices.iter().map(|&i| ds.y[i] as i64).collect();
        assert_eq!(classes.len(), 4);
    }

    #[test]
    fn aggregator_routes_but_cannot_open() {
        // Structural privacy check: all coreset traffic flows through the
        // aggregator and the envelope body differs from the plaintext.
        let mut rng = crate::util::rng::Rng::new(6);
        let ds = synth::blobs("t", 100, 6, 2, 1, 3.0, 1.0, &mut rng);
        let part = VerticalPartition::even(6, 3);
        let slices: Vec<Matrix> = (0..3).map(|c| part.slice(&ds.x, c)).collect();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        let r = run(
            &slices,
            &ds.y,
            true,
            &ClusterCoresetConfig::default(),
            &NativeAssign,
            &net,
            &he,
        )
        .unwrap();
        let agg_bytes = meter.party_bytes(PartyId::Aggregator, "coreset/");
        assert_eq!(
            agg_bytes,
            meter.total_bytes("coreset/"),
            "every coreset byte transits the aggregator"
        );
        assert_eq!(
            r.bytes,
            meter.total_bytes("coreset/"),
            "engine bookkeeping equals middleware accounting"
        );
    }

    #[test]
    fn result_invariant_under_thread_count() {
        // The per-party fan-out is order-preserving and the HE/meter phase
        // stays serialized, so the coreset must be identical at any thread
        // count — the property that makes `threads` a pure perf knob.
        let mut rng = crate::util::rng::Rng::new(7);
        let ds = synth::blobs("t", 300, 9, 2, 2, 3.0, 1.0, &mut rng);
        let part = VerticalPartition::even(9, 3);
        let slices: Vec<Matrix> = (0..3).map(|c| part.slice(&ds.x, c)).collect();
        let run_with = |threads: usize| {
            let net = ChannelTransport::new();
            let he = HeContext::for_tests();
            let cfg = ClusterCoresetConfig { threads, ..Default::default() };
            run(&slices, &ds.y, true, &cfg, &NativeAssign, &net, &he).unwrap()
        };
        let serial = run_with(1);
        for threads in [2usize, 4] {
            let par = run_with(threads);
            assert_eq!(par.indices, serial.indices, "threads={threads}");
            assert_eq!(par.weights, serial.weights, "threads={threads}");
            assert_eq!(par.bytes, serial.bytes, "threads={threads}");
        }
    }
}
