//! V-coreset baseline (Huang et al., NeurIPS 2022) — the comparison in
//! Fig. 6.
//!
//! V-coreset builds task-specific coresets for VFL:
//! * **regularized linear regression** — leverage-score sampling: clients
//!   exchange projections onto an orthonormal basis of their local
//!   features (which is exactly the label/feature leakage the paper
//!   criticizes), sample ∝ leverage, weight 1/(s·p_i);
//! * **k-means** — sensitivity sampling: s_i ∝ dist_i²/Σdist² + 1/n.
//!
//! It supports only these two tasks (no classification heads) — we follow
//! the original and, like the paper's Fig. 6, evaluate it by training the
//! downstream model on its (sample, weight) output at a matched size.

use crate::data::Matrix;
use crate::ml::kmeans::{KMeans, NativeAssign};
use crate::util::rng::Rng;

/// A sampled coreset: indices + importance weights.
#[derive(Clone, Debug)]
pub struct VCoreset {
    pub indices: Vec<usize>,
    pub weights: Vec<f32>,
}

/// Leverage scores of the rows of X via Gram–Schmidt on columns.
/// ℓ_i = |Q_i,:|² where Q is an orthonormal basis of the column space.
pub fn leverage_scores(x: &Matrix) -> Vec<f32> {
    let (n, d) = x.shape();
    // Modified Gram–Schmidt over columns.
    let mut q: Vec<Vec<f32>> = Vec::with_capacity(d);
    for j in 0..d {
        let mut col: Vec<f32> = (0..n).map(|r| x.get(r, j)).collect();
        let orig_norm: f32 = col.iter().map(|v| v * v).sum::<f32>().sqrt();
        for prev in &q {
            let dot: f32 = col.iter().zip(prev).map(|(a, b)| a * b).sum();
            for (c, p) in col.iter_mut().zip(prev) {
                *c -= dot * p;
            }
        }
        let norm: f32 = col.iter().map(|v| v * v).sum::<f32>().sqrt();
        // Relative threshold: f32 Gram–Schmidt leaves ~1e-4·|col| residue
        // on exactly dependent columns.
        if norm > 1e-4 * orig_norm.max(1e-12) {
            for c in &mut col {
                *c /= norm;
            }
            q.push(col);
        }
    }
    let mut lev = vec![0.0f32; n];
    for col in &q {
        for (l, v) in lev.iter_mut().zip(col) {
            *l += v * v;
        }
    }
    lev
}

/// Importance-sample `size` rows with probabilities ∝ score (+uniform
/// smoothing), weights 1/(size·p_i).
fn importance_sample(scores: &[f32], size: usize, rng: &mut Rng) -> VCoreset {
    let n = scores.len();
    let size = size.min(n);
    let total: f64 = scores.iter().map(|&s| s as f64).sum();
    // Smooth with a uniform component (standard sensitivity bound).
    let probs: Vec<f64> = scores
        .iter()
        .map(|&s| 0.5 * (s as f64 / total.max(1e-12)) + 0.5 / n as f64)
        .collect();
    // Sample WITH replacement (the theory's regime), dedup to an index set
    // accumulating weight per repeat.
    let mut acc: std::collections::HashMap<usize, f64> = std::collections::HashMap::new();
    let cum: Vec<f64> = probs
        .iter()
        .scan(0.0, |a, &p| {
            *a += p;
            Some(*a)
        })
        .collect();
    let norm = *cum.last().unwrap();
    for _ in 0..size {
        let t = rng.f64() * norm;
        let idx = cum.partition_point(|&c| c < t).min(n - 1);
        *acc.entry(idx).or_insert(0.0) += 1.0 / (size as f64 * probs[idx]);
    }
    let mut indices: Vec<usize> = acc.keys().copied().collect();
    indices.sort_unstable();
    let weights = indices.iter().map(|i| acc[i] as f32).collect();
    VCoreset { indices, weights }
}

/// V-coreset for (regularized) linear regression: leverage sampling over
/// the concatenated client projections. `slices` are per-client feature
/// matrices (the exchange of projections is V-coreset's privacy leak).
pub fn for_regression(slices: &[Matrix], size: usize, seed: u64) -> VCoreset {
    let refs: Vec<&Matrix> = slices.iter().collect();
    let x = Matrix::hcat(&refs).expect("aligned slices");
    let lev = leverage_scores(&x);
    importance_sample(&lev, size, &mut Rng::new(seed))
}

/// V-coreset for k-means (used for classification comparisons in Fig. 6):
/// sensitivity sampling from a pilot clustering.
pub fn for_kmeans(slices: &[Matrix], k: usize, size: usize, seed: u64) -> VCoreset {
    let refs: Vec<&Matrix> = slices.iter().collect();
    let x = Matrix::hcat(&refs).expect("aligned slices");
    let mut km = KMeans::new(k);
    km.seed = seed;
    let fit = km.fit(&x, &NativeAssign);
    let sens: Vec<f32> = fit.dist.iter().map(|&d| d * d).collect();
    importance_sample(&sens, size, &mut Rng::new(seed ^ 0x5EED))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn leverage_scores_sum_to_rank() {
        let mut rng = Rng::new(1);
        let x = Matrix::from_fn(50, 4, |_, _| rng.gaussian_f32());
        let lev = leverage_scores(&x);
        let sum: f32 = lev.iter().sum();
        assert!((sum - 4.0).abs() < 1e-2, "Σℓ = rank: {sum}");
        assert!(lev.iter().all(|&l| (0.0..=1.0 + 1e-4).contains(&l)));
    }

    #[test]
    fn rank_deficient_handled() {
        // Column 1 = 2 × column 0 → rank 1.
        let x = Matrix::from_fn(20, 2, |r, c| (r as f32 + 1.0) * (c as f32 + 1.0));
        let lev = leverage_scores(&x);
        let sum: f32 = lev.iter().sum();
        assert!((sum - 1.0).abs() < 1e-2, "{sum}");
    }

    #[test]
    fn sampling_respects_size_and_weights_positive() {
        let mut rng = Rng::new(2);
        let ds = synth::regression("t", 300, 6, &mut rng);
        let v = for_regression(&[ds.x.clone()], 50, 3);
        assert!(v.indices.len() <= 50);
        assert!(!v.indices.is_empty());
        assert!(v.weights.iter().all(|&w| w > 0.0));
        assert!(v.indices.windows(2).all(|p| p[0] < p[1]));
    }

    #[test]
    fn weights_estimate_total_mass() {
        // E[Σ w_i] = n for importance sampling with weight 1/(s·p).
        let mut rng = Rng::new(3);
        let ds = synth::regression("t", 400, 5, &mut rng);
        let v = for_regression(&[ds.x.clone()], 200, 4);
        let total: f32 = v.weights.iter().sum();
        assert!(
            (total - 400.0).abs() / 400.0 < 0.35,
            "Σw = {total}, expect ≈ 400"
        );
    }

    #[test]
    fn kmeans_variant_prefers_far_points() {
        let mut rng = Rng::new(4);
        let ds = synth::blobs("t", 500, 6, 2, 2, 4.0, 0.8, &mut rng);
        let v = for_kmeans(&[ds.x.clone()], 4, 100, 5);
        assert!(!v.indices.is_empty());
        assert!(v.indices.len() <= 100);
    }
}
