//! Pairing/scheduling strategies for MPSI rounds (paper §4.1,
//! "Scheduling optimization").
//!
//! Given the active clients `U` with their current result lengths
//! (`ResLen`), produce the round's TPSI pairs and role assignment:
//!
//! * **RequestOrder** (baseline): pair sequentially by request order;
//!   earlier requester = sender.
//! * **VolumeAware** (the paper's optimization): `AsSort` ascending by
//!   ResLen, pair `c_k` with `c_(k+⌈|U|/2⌉)`; for RSA the smaller party is
//!   receiver, for OT the larger party is receiver. When |U| is odd the
//!   middle client gets a bye.

use super::TpsiKind;

/// One scheduled TPSI pair: indices into the active-client list.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct ScheduledPair {
    pub sender: usize,
    pub receiver: usize,
}

/// Round schedule: pairs plus an optional bye (odd |U|).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct RoundSchedule {
    pub pairs: Vec<ScheduledPair>,
    pub bye: Option<usize>,
}

/// Pairing strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Pairing {
    RequestOrder,
    VolumeAware,
}

/// An active client as seen by the scheduler: (stable id, ResLen).
pub type Active = (usize, u64);

/// Build the round schedule. Returned indices are the stable ids from the
/// `active` list (NOT positions), so engines can map them back to clients.
pub fn schedule(active: &[Active], pairing: Pairing, kind: TpsiKind) -> RoundSchedule {
    match pairing {
        Pairing::RequestOrder => request_order(active, kind),
        Pairing::VolumeAware => volume_aware(active, kind),
    }
}

fn request_order(active: &[Active], _kind: TpsiKind) -> RoundSchedule {
    let mut pairs = Vec::new();
    let mut i = 0;
    while i + 1 < active.len() {
        // Paper step 2: earlier requester is the sender.
        pairs.push(ScheduledPair { sender: active[i].0, receiver: active[i + 1].0 });
        i += 2;
    }
    let bye = (active.len() % 2 == 1).then(|| active[active.len() - 1].0);
    RoundSchedule { pairs, bye }
}

fn volume_aware(active: &[Active], kind: TpsiKind) -> RoundSchedule {
    // AsSort: ascending by ResLen (ties broken by id for determinism).
    let mut sorted: Vec<Active> = active.to_vec();
    sorted.sort_by_key(|&(id, len)| (len, id));
    let u = sorted.len();
    let half = u.div_ceil(2); // ⌈|U|/2⌉
    let mut pairs = Vec::new();
    // Pair c_k with c_{k+⌈U/2⌉} for k = 1..⌊U/2⌋ (1-based in the paper).
    for k in 0..u / 2 {
        let small = sorted[k]; // fewer samples
        let large = sorted[k + half]; // more samples
        let (sender, receiver) = match kind {
            // RSA: receiver's elements cross the wire twice → receiver = small.
            TpsiKind::Rsa => (large.0, small.0),
            // OT: sender ships the expensive mapped set → sender = small.
            TpsiKind::Ot => (small.0, large.0),
        };
        pairs.push(ScheduledPair { sender, receiver });
    }
    // Odd |U|: the middle client (index ⌈U/2⌉, 1-based) pairs with itself.
    let bye = (u % 2 == 1).then(|| sorted[half - 1].0);
    RoundSchedule { pairs, bye }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ids(s: &RoundSchedule) -> Vec<usize> {
        let mut v: Vec<usize> = s
            .pairs
            .iter()
            .flat_map(|p| [p.sender, p.receiver])
            .chain(s.bye)
            .collect();
        v.sort_unstable();
        v
    }

    #[test]
    fn request_order_pairs_adjacent() {
        let active = [(10, 5), (11, 50), (12, 7), (13, 9)];
        let s = schedule(&active, Pairing::RequestOrder, TpsiKind::Rsa);
        assert_eq!(
            s.pairs,
            vec![
                ScheduledPair { sender: 10, receiver: 11 },
                ScheduledPair { sender: 12, receiver: 13 },
            ]
        );
        assert_eq!(s.bye, None);
    }

    #[test]
    fn every_client_appears_exactly_once() {
        for n in 1..=9usize {
            let active: Vec<Active> = (0..n).map(|i| (i, (i * 13 % 7) as u64)).collect();
            for pairing in [Pairing::RequestOrder, Pairing::VolumeAware] {
                for kind in [TpsiKind::Rsa, TpsiKind::Ot] {
                    let s = schedule(&active, pairing, kind);
                    assert_eq!(ids(&s), (0..n).collect::<Vec<_>>(), "{pairing:?} {kind:?} n={n}");
                    assert_eq!(s.pairs.len(), n / 2);
                    assert_eq!(s.bye.is_some(), n % 2 == 1);
                }
            }
        }
    }

    #[test]
    fn volume_aware_pairs_small_with_large() {
        // Sizes 10,20,30,40 → sorted pairs (10,30), (20,40).
        let active = [(0, 40), (1, 10), (2, 30), (3, 20)];
        let s = schedule(&active, Pairing::VolumeAware, TpsiKind::Rsa);
        // RSA: small is receiver.
        assert_eq!(
            s.pairs,
            vec![
                ScheduledPair { sender: 2, receiver: 1 }, // 30 sends to 10
                ScheduledPair { sender: 0, receiver: 3 }, // 40 sends to 20
            ]
        );
    }

    #[test]
    fn ot_roles_are_flipped() {
        let active = [(0, 40), (1, 10), (2, 30), (3, 20)];
        let s = schedule(&active, Pairing::VolumeAware, TpsiKind::Ot);
        // OT: large is receiver ⇒ small is sender.
        assert_eq!(
            s.pairs,
            vec![
                ScheduledPair { sender: 1, receiver: 2 },
                ScheduledPair { sender: 3, receiver: 0 },
            ]
        );
    }

    #[test]
    fn odd_count_bye_is_middle_by_volume() {
        // Sizes 1,2,3,4,5 → half=3 → pairs (1,4),(2,5); bye = 3.
        let active = [(0, 5), (1, 4), (2, 3), (3, 2), (4, 1)];
        let s = schedule(&active, Pairing::VolumeAware, TpsiKind::Rsa);
        assert_eq!(s.bye, Some(2)); // the ResLen=3 client
        assert_eq!(s.pairs.len(), 2);
    }

    #[test]
    fn single_client_gets_bye() {
        let s = schedule(&[(9, 100)], Pairing::VolumeAware, TpsiKind::Rsa);
        assert!(s.pairs.is_empty());
        assert_eq!(s.bye, Some(9));
    }

    /// Random actives (non-contiguous ids, arbitrary ResLens); returns the
    /// common generator for the VolumeAware property tests below.
    fn gen_actives(r: &mut crate::util::rng::Rng) -> Vec<Active> {
        let n = 1 + r.below_usize(12);
        (0..n).map(|i| (i * 3 + 5, r.below(1_000))).collect()
    }

    #[test]
    fn volume_aware_pairs_follow_assort_formula() {
        // Paper §4.1: AsSort ascending by ResLen, pair c_k ↔ c_(k+⌈|U|/2⌉)
        // (1-based); odd |U| leaves the middle client (index ⌈|U|/2⌉) a bye.
        crate::util::check::forall(
            crate::util::check::Config { cases: 128, seed: 0x5C4ED },
            gen_actives,
            |active| {
                let mut sorted = active.clone();
                sorted.sort_by_key(|&(id, len)| (len, id));
                let u = sorted.len();
                let half = u.div_ceil(2);
                let s = schedule(active, Pairing::VolumeAware, TpsiKind::Rsa);
                if s.pairs.len() != u / 2 {
                    return false;
                }
                for (k, p) in s.pairs.iter().enumerate() {
                    // RSA roles: small party receives, large party sends.
                    if p.receiver != sorted[k].0 || p.sender != sorted[k + half].0 {
                        return false;
                    }
                }
                s.bye == (u % 2 == 1).then(|| sorted[half - 1].0)
            },
        );
    }

    #[test]
    fn volume_aware_roles_by_protocol() {
        // RSA: the receiver's elements cross the wire twice, so the party
        // with fewer samples receives. OT: the sender ships the expensive
        // mapped set, so the party with fewer samples sends (receiver is
        // the larger one).
        crate::util::check::forall(
            crate::util::check::Config { cases: 128, seed: 0x707E5 },
            gen_actives,
            |active| {
                let len_of = |id: usize| active.iter().find(|a| a.0 == id).unwrap().1;
                let rsa = schedule(active, Pairing::VolumeAware, TpsiKind::Rsa);
                let ot = schedule(active, Pairing::VolumeAware, TpsiKind::Ot);
                rsa.pairs
                    .iter()
                    .all(|p| len_of(p.receiver) <= len_of(p.sender))
                    && ot.pairs.iter().all(|p| len_of(p.receiver) >= len_of(p.sender))
            },
        );
    }

    #[test]
    fn volume_aware_odd_bye_is_volume_median() {
        // The bye never goes to an extreme: at least ⌊|U|/2⌋ clients hold
        // no more than the bye's ResLen and at least ⌊|U|/2⌋ hold no less.
        crate::util::check::forall(
            crate::util::check::Config { cases: 128, seed: 0xB1E },
            |r| {
                let mut a = gen_actives(r);
                if a.len() % 2 == 0 {
                    a.pop();
                }
                a
            },
            |active| {
                let s = schedule(active, Pairing::VolumeAware, TpsiKind::Ot);
                let Some(bye) = s.bye else { return false };
                let bye_len = active.iter().find(|a| a.0 == bye).unwrap().1;
                let below = active.iter().filter(|a| a.1 <= bye_len).count();
                let above = active.iter().filter(|a| a.1 >= bye_len).count();
                below > active.len() / 2 && above > active.len() / 2
            },
        );
    }
}
