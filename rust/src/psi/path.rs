//! Path-MPSI baseline: strictly sequential chain of two-party PSIs.
//!
//! Client 0 intersects with client 1; the running result then intersects
//! with client 2, and so on — O(m) rounds with zero parallelism, the
//! configuration the paper's Fig. 7 shows losing to Tree-MPSI.

use crate::error::Result;
use crate::net::{PartyId, Transport};
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::common::{allocate_result, HeContext};
use super::tree::derive_seed;
use super::{MpsiReport, RoundReport, TpsiProtocol};

/// Run Path-MPSI. The running intersection moves down the chain; each hop
/// makes the next client the receiver (it stores the new result), matching
/// the paper's description of the path topology. The hops are strictly
/// sequential, so each hop's batch crypto gets the whole `par` budget.
pub fn run_path(
    sets: &[Vec<u64>],
    protocol: &TpsiProtocol,
    seed: u64,
    net: &dyn Transport,
    par: Parallel,
    he: &HeContext,
) -> Result<MpsiReport> {
    assert!(!sets.is_empty());
    let total_sw = Stopwatch::start();
    let m = sets.len();
    let mut holder = 0usize;
    let mut result = sets[0].clone();
    let mut rounds = Vec::new();
    let mut sim_total = 0.0;
    let mut total_bytes = 0u64;

    for next in 1..m {
        let sw = Stopwatch::start();
        let phase = format!("psi/hop{next}");
        let out = protocol.run(
            &result,
            &sets[next],
            net,
            PartyId::Client(holder as u32),
            PartyId::Client(next as u32),
            &phase,
            derive_seed(seed, next as u32, 0),
            par,
        )?;
        let inter = out.intersection;
        // Strictly sequential chain: every hop's compute + wire adds up.
        let hop_sim = out.cost.sim_s + out.cost.wall_s;
        rounds.push(RoundReport {
            pairs: vec![(holder as u32, next as u32, inter.len())],
            sim_s: hop_sim,
            wall_s: sw.elapsed_secs(),
            bytes: out.cost.total_bytes(),
        });
        sim_total += hop_sim;
        total_bytes += out.cost.total_bytes();
        result = inter;
        holder = next;
    }

    result.sort_unstable();
    let mut rng = Rng::new(seed ^ 0xBEEF);
    let alloc = allocate_result(
        holder as u32,
        m as u32,
        &result,
        he,
        net,
        "psi/alloc",
        &mut rng,
        par,
    )?;
    sim_total += alloc.sim_s;
    total_bytes += alloc.bytes;

    Ok(MpsiReport {
        intersection: result,
        total_bytes,
        rounds,
        wall_s: total_sw.elapsed_secs(),
        sim_s: sim_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};
    use crate::psi::oracle_intersection;

    fn run(sets: &[Vec<u64>]) -> MpsiReport {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        run_path(sets, &TpsiProtocol::ot(), 5, &net, Parallel::new(2), &he).unwrap()
    }

    #[test]
    fn matches_oracle() {
        let sets = vec![
            vec![1, 2, 3, 4],
            vec![2, 3, 4, 5],
            vec![3, 4, 5, 6],
            vec![4, 3, 0, 1],
        ];
        assert_eq!(run(&sets).intersection, oracle_intersection(&sets));
    }

    #[test]
    fn rounds_are_m_minus_1() {
        let sets: Vec<Vec<u64>> = (0..7).map(|_| (0..10).collect()).collect();
        assert_eq!(run(&sets).num_rounds(), 6);
    }

    #[test]
    fn sim_time_is_serialized_sum() {
        let sets: Vec<Vec<u64>> = (0..4).map(|_| (0..100).collect()).collect();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        let r = run_path(&sets, &TpsiProtocol::ot(), 5, &net, Parallel::serial(), &he).unwrap();
        let hop_sum: f64 = r.rounds.iter().map(|x| x.sim_s).sum();
        // Total sim = hops + allocation; hops dominate and are summed.
        assert!(r.sim_s >= hop_sum);
    }
}
