//! **Tree-MPSI** — the paper's multi-party PSI (§4.1).
//!
//! Each round: active clients request alignment from the aggregation
//! server (step 1), the server pairs them (step 2, [`sched`]), notifies
//! partners (step 3), pairs run two-party PSI *concurrently* (step 4), and
//! each pair's receiver stays active holding the intersection while the
//! sender retires. After ⌈log₂ m⌉ rounds one client holds the final result
//! and allocates it to everyone through the HE envelope (steps 5–6).
//!
//! Concurrency is real (pairs execute on the thread pool), and the
//! simulated communication makespan takes the *max* over a round's pairs —
//! the source of the paper's ~2.25× speedup over Path/Star.

use crate::net::{Meter, PartyId};
use crate::util::pool::ThreadPool;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::common::{allocate_result, charge_round_scheduling, HeContext};
use super::sched::{schedule, Pairing};
use super::{MpsiReport, RoundReport, TpsiProtocol};

/// Tree-MPSI configuration.
#[derive(Clone)]
pub struct TreeMpsiConfig {
    pub protocol: TpsiProtocol,
    pub pairing: Pairing,
    pub seed: u64,
}

impl Default for TreeMpsiConfig {
    fn default() -> Self {
        TreeMpsiConfig {
            protocol: TpsiProtocol::rsa(),
            pairing: Pairing::VolumeAware,
            seed: 0xA11_CE,
        }
    }
}

/// Run Tree-MPSI over the clients' indicator sets.
pub fn run_tree(
    sets: &[Vec<u64>],
    cfg: &TreeMpsiConfig,
    meter: &Meter,
    pool: &ThreadPool,
    he: &HeContext,
) -> MpsiReport {
    assert!(!sets.is_empty(), "need at least one client");
    let total_sw = Stopwatch::start();
    let m = sets.len();
    let mut current: Vec<Vec<u64>> = sets.to_vec();
    let mut active: Vec<usize> = (0..m).collect();
    let mut rounds = Vec::new();
    let mut sim_total = 0.0;
    let mut round_no = 0u32;

    while active.len() > 1 {
        let round_sw = Stopwatch::start();
        let phase = format!("psi/round{round_no}");
        let actives: Vec<(usize, u64)> =
            active.iter().map(|&id| (id, current[id].len() as u64)).collect();
        let sched_sim = charge_round_scheduling(&actives, round_no, meter, &phase);

        let plan = schedule(&actives, cfg.pairing, cfg.protocol.kind());

        // Launch every pair concurrently on the pool.
        let jobs: Vec<_> = plan
            .pairs
            .iter()
            .enumerate()
            .map(|(pair_idx, p)| {
                let protocol = cfg.protocol.clone();
                let sender_set = current[p.sender].clone();
                let receiver_set = current[p.receiver].clone();
                let (s_id, r_id) = (p.sender as u32, p.receiver as u32);
                let phase = phase.clone();
                let seed = derive_seed(cfg.seed, round_no, pair_idx as u64);
                let meter_ref: &Meter = meter;
                move || {
                    let out = protocol.run(
                        &sender_set,
                        &receiver_set,
                        meter_ref,
                        PartyId::Client(s_id),
                        PartyId::Client(r_id),
                        &phase,
                        seed,
                    );
                    (s_id, r_id, out)
                }
            })
            .collect();
        let outcomes = run_scoped(pool, jobs);

        // Fold results: receivers keep intersections, senders retire.
        let mut report = RoundReport { sim_s: sched_sim, ..Default::default() };
        let mut next_active = Vec::new();
        let mut max_pair_sim = 0.0f64;
        for (s_id, r_id, out) in outcomes {
            report.bytes += out.cost.total_bytes();
            // Distributed makespan: pairs run on disjoint machine pairs, so
            // the round costs the slowest pair (compute + wire).
            max_pair_sim = max_pair_sim.max(out.cost.sim_s + out.cost.wall_s);
            report.pairs.push((s_id, r_id, out.intersection.len()));
            current[r_id as usize] = out.intersection;
            next_active.push(r_id as usize);
        }
        if let Some(bye) = plan.bye {
            next_active.push(bye);
        }
        next_active.sort_unstable();
        active = next_active;
        report.sim_s += max_pair_sim;
        report.wall_s = round_sw.elapsed_secs();
        sim_total += report.sim_s;
        rounds.push(report);
        round_no += 1;
    }

    // Result allocation (steps 5–6).
    let holder = active[0] as u32;
    let mut result = current[active[0]].clone();
    result.sort_unstable();
    let mut rng = Rng::new(cfg.seed ^ 0xEE);
    sim_total += allocate_result(holder, m as u32, &result, he, meter, "psi/alloc", &mut rng);

    MpsiReport {
        intersection: result,
        total_bytes: meter.total_bytes("psi/"),
        rounds,
        wall_s: total_sw.elapsed_secs(),
        sim_s: sim_total,
    }
}

/// Derive a per-pair deterministic seed.
pub(crate) fn derive_seed(base: u64, round: u32, pair: u64) -> u64 {
    base ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ pair.wrapping_mul(0xBF58_476D_1CE4_E5B9)
}

/// Run a round's pair jobs.
///
/// When the host has spare cores, pairs run on scoped threads (真 parallel
/// wall-clock); on constrained hosts they run sequentially so each pair's
/// measured compute time is uncontended — that solo measurement is what
/// the round-makespan model (`max` over pairs) needs to be meaningful.
/// Correctness is identical either way.
fn run_scoped<'a, T: Send + 'a>(
    _pool: &ThreadPool,
    jobs: Vec<impl FnOnce() -> T + Send + 'a>,
) -> Vec<T> {
    let cores = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    if cores >= 2 * jobs.len().max(1) {
        std::thread::scope(|s| {
            let handles: Vec<_> = jobs.into_iter().map(|j| s.spawn(j)).collect();
            handles.into_iter().map(|h| h.join().expect("pair panicked")).collect()
        })
    } else {
        jobs.into_iter().map(|j| j()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetConfig;
    use crate::psi::oracle_intersection;
    use crate::psi::sched::Pairing;
    use crate::util::check;

    fn fast_rsa() -> TpsiProtocol {
        TpsiProtocol::Rsa(super::super::rsa_psi::RsaPsiConfig {
            modulus_bits: 256,
            domain: "t".into(),
        })
    }

    fn run(sets: &[Vec<u64>], protocol: TpsiProtocol, pairing: Pairing) -> MpsiReport {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let pool = ThreadPool::new(4);
        let he = HeContext::for_tests();
        let cfg = TreeMpsiConfig { protocol, pairing, seed: 11 };
        run_tree(sets, &cfg, &meter, &pool, &he)
    }

    #[test]
    fn matches_oracle_rsa() {
        let sets = vec![
            vec![1, 2, 3, 4, 5, 6],
            vec![4, 5, 6, 7, 8],
            vec![5, 6, 4, 9],
            vec![6, 5, 4, 0],
        ];
        let r = run(&sets, fast_rsa(), Pairing::VolumeAware);
        assert_eq!(r.intersection, oracle_intersection(&sets));
    }

    #[test]
    fn matches_oracle_ot_many_clients() {
        check::forall(
            check::Config { cases: 12, seed: 3 },
            |rng| {
                let m = 2 + rng.below_usize(7);
                (0..m)
                    .map(|_| {
                        let n = 10 + rng.below_usize(40);
                        check::gen_index_set(rng, n, 80)
                    })
                    .collect::<Vec<_>>()
            },
            |sets| {
                let r = run(sets, TpsiProtocol::ot(), Pairing::VolumeAware);
                r.intersection == oracle_intersection(sets)
            },
        );
    }

    #[test]
    fn round_count_is_log_m() {
        for m in [2usize, 3, 4, 5, 8, 10, 16] {
            let sets: Vec<Vec<u64>> = (0..m).map(|_| (0..20).collect()).collect();
            let r = run(&sets, TpsiProtocol::ot(), Pairing::VolumeAware);
            let expect = (m as f64).log2().ceil() as usize;
            assert_eq!(r.num_rounds(), expect, "m={m}");
        }
    }

    #[test]
    fn single_client_short_circuits() {
        let r = run(&[vec![3, 1, 2]], TpsiProtocol::ot(), Pairing::VolumeAware);
        assert_eq!(r.intersection, vec![1, 2, 3]);
        assert_eq!(r.num_rounds(), 0);
    }

    #[test]
    fn request_order_also_correct() {
        let sets = vec![vec![1, 2, 3], vec![2, 3, 4], vec![3, 2, 9]];
        let r = run(&sets, fast_rsa(), Pairing::RequestOrder);
        assert_eq!(r.intersection, vec![2, 3]);
    }

    #[test]
    fn tree_makespan_beats_path_and_star() {
        // The Fig. 7 invariant: with many equal clients, Tree's simulated
        // distributed time is well below Path's and Star's (O(log m) rounds
        // of concurrent pairs vs O(m) serialized pairs).
        let sets: Vec<Vec<u64>> = (0..8).map(|_| (0..300).collect()).collect();
        let he = HeContext::for_tests();
        let pool = ThreadPool::new(4);
        let cfg = TreeMpsiConfig {
            protocol: fast_rsa(),
            pairing: Pairing::VolumeAware,
            seed: 1,
        };
        let meter = Meter::new(NetConfig::lan_10gbps());
        let tree = run_tree(&sets, &cfg, &meter, &pool, &he);
        let meter = Meter::new(NetConfig::lan_10gbps());
        let path = crate::psi::path::run_path(&sets, &fast_rsa(), 1, &meter, &he);
        let meter = Meter::new(NetConfig::lan_10gbps());
        let star = crate::psi::star::run_star(&sets, &fast_rsa(), 0, 1, &meter, &he);
        assert!(
            tree.sim_s < path.sim_s * 0.7,
            "tree {} vs path {}",
            tree.sim_s,
            path.sim_s
        );
        assert!(
            tree.sim_s < star.sim_s * 0.7,
            "tree {} vs star {}",
            tree.sim_s,
            star.sim_s
        );
    }
}
