//! **Tree-MPSI** — the paper's multi-party PSI (§4.1).
//!
//! Each round: active clients request alignment from the aggregation
//! server (step 1), the server pairs them (step 2, [`sched`](super::sched))
//! and notifies partners (step 3) — the `PsiRequest`/`PsiSchedule`
//! messages travel over the [`Transport`] and the engine executes whatever
//! plan the clients decode — then pairs run two-party PSI *concurrently*
//! (step 4), and each pair's receiver stays active holding the
//! intersection while the sender retires. After ⌈log₂ m⌉ rounds one client
//! holds the final result and allocates it to everyone through the HE
//! envelope (steps 5–6).
//!
//! Concurrency is real (pairs execute on scoped worker threads, capped by
//! the configured [`Parallel`] budget — `--threads 1` serializes alignment
//! like every other phase), and the simulated communication makespan takes
//! the *max* over a round's pairs — the source of the paper's ~2.25×
//! speedup over Path/Star.

use crate::error::Result;
use crate::net::Transport;
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::common::{allocate_result, exchange_round_schedule, HeContext};
use super::sched::Pairing;
use super::{MpsiReport, RoundReport, TpsiProtocol};

/// Tree-MPSI configuration.
#[derive(Clone)]
pub struct TreeMpsiConfig {
    pub protocol: TpsiProtocol,
    pub pairing: Pairing,
    pub seed: u64,
}

impl Default for TreeMpsiConfig {
    fn default() -> Self {
        TreeMpsiConfig {
            protocol: TpsiProtocol::rsa(),
            pairing: Pairing::VolumeAware,
            seed: 0xA11_CE,
        }
    }
}

/// Run Tree-MPSI over the clients' indicator sets.
///
/// `par` bounds the worker threads pair executions may occupy — the same
/// budget every other hot path takes from `PipelineConfig::threads`.
pub fn run_tree(
    sets: &[Vec<u64>],
    cfg: &TreeMpsiConfig,
    net: &dyn Transport,
    par: Parallel,
    he: &HeContext,
) -> Result<MpsiReport> {
    assert!(!sets.is_empty(), "need at least one client");
    let total_sw = Stopwatch::start();
    let m = sets.len();
    let mut current: Vec<Vec<u64>> = sets.to_vec();
    let mut active: Vec<usize> = (0..m).collect();
    let mut rounds = Vec::new();
    let mut sim_total = 0.0;
    let mut total_bytes = 0u64;
    let mut round_no = 0u32;

    while active.len() > 1 {
        let round_sw = Stopwatch::start();
        let phase = format!("psi/round{round_no}");
        let actives: Vec<(usize, u64)> =
            active.iter().map(|&id| (id, current[id].len() as u64)).collect();
        let (plan, sched_flow) = exchange_round_schedule(
            &actives,
            round_no,
            cfg.pairing,
            cfg.protocol.kind(),
            net,
            &phase,
        )?;
        total_bytes += sched_flow.bytes;

        // Launch every pair concurrently on scoped workers. The budget
        // splits across the two parallel levels — pair fan-out takes
        // `outer` workers, each pair's batch crypto gets the leftover —
        // so they compose to ~par.threads() instead of multiplying.
        // Early rounds parallelize across pairs; the final rounds (few
        // pairs) recover the idle workers inside the pair's crypto plane.
        let outer = par.threads().min(plan.pairs.len().max(1));
        let inner = Parallel::new((par.threads() / outer).max(1));
        let jobs: Vec<_> = plan
            .pairs
            .iter()
            .enumerate()
            .map(|(pair_idx, p)| {
                let protocol = cfg.protocol.clone();
                let sender_set = current[p.sender].clone();
                let receiver_set = current[p.receiver].clone();
                let (s_id, r_id) = (p.sender as u32, p.receiver as u32);
                let phase = phase.clone();
                let seed = derive_seed(cfg.seed, round_no, pair_idx as u64);
                move || {
                    let out = protocol.run(
                        &sender_set,
                        &receiver_set,
                        net,
                        crate::net::PartyId::Client(s_id),
                        crate::net::PartyId::Client(r_id),
                        &phase,
                        seed,
                        inner,
                    )?;
                    Ok((s_id, r_id, out))
                }
            })
            .collect();
        let outcomes: Vec<(u32, u32, super::TpsiOutcome)> = run_scoped(par, jobs)
            .into_iter()
            .collect::<Result<_>>()?;

        // Fold results: receivers keep intersections, senders retire.
        let mut report = RoundReport { sim_s: sched_flow.sim_s, ..Default::default() };
        let mut next_active = Vec::new();
        let mut max_pair_sim = 0.0f64;
        for (s_id, r_id, out) in outcomes {
            report.bytes += out.cost.total_bytes();
            // Distributed makespan: pairs run on disjoint machine pairs, so
            // the round costs the slowest pair (compute + wire).
            max_pair_sim = max_pair_sim.max(out.cost.sim_s + out.cost.wall_s);
            report.pairs.push((s_id, r_id, out.intersection.len()));
            current[r_id as usize] = out.intersection;
            next_active.push(r_id as usize);
        }
        if let Some(bye) = plan.bye {
            next_active.push(bye);
        }
        next_active.sort_unstable();
        active = next_active;
        total_bytes += report.bytes;
        report.sim_s += max_pair_sim;
        report.wall_s = round_sw.elapsed_secs();
        sim_total += report.sim_s;
        rounds.push(report);
        round_no += 1;
    }

    // Result allocation (steps 5–6).
    let holder = active[0] as u32;
    let mut result = current[active[0]].clone();
    result.sort_unstable();
    let mut rng = Rng::new(cfg.seed ^ 0xEE);
    let alloc =
        allocate_result(holder, m as u32, &result, he, net, "psi/alloc", &mut rng, par)?;
    sim_total += alloc.sim_s;
    total_bytes += alloc.bytes;

    Ok(MpsiReport {
        intersection: result,
        total_bytes,
        rounds,
        wall_s: total_sw.elapsed_secs(),
        sim_s: sim_total,
    })
}

/// Derive a per-pair deterministic seed.
pub(crate) fn derive_seed(base: u64, round: u32, pair: u64) -> u64 {
    base ^ (round as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ pair.wrapping_mul(0xBF58_476D_1CE4_E5B9)
}

/// Run a round's pair jobs on at most `par.threads()` scoped workers,
/// returning results in submission order.
///
/// With a budget of 1 the pairs run strictly sequentially (each pair's
/// measured compute time is uncontended — what the round-makespan model
/// needs on constrained hosts); larger budgets split the pairs into
/// contiguous groups, one scoped worker per group. Correctness is
/// identical at any setting.
fn run_scoped<'a, T: Send + 'a>(
    par: Parallel,
    jobs: Vec<impl FnOnce() -> T + Send + 'a>,
) -> Vec<T> {
    let t = par.threads().min(jobs.len());
    if t <= 1 {
        return jobs.into_iter().map(|j| j()).collect();
    }
    let n = jobs.len();
    let base = n / t;
    let extra = n % t;
    let mut it = jobs.into_iter();
    let groups: Vec<Vec<_>> = (0..t)
        .map(|i| (&mut it).take(base + usize::from(i < extra)).collect())
        .collect();
    std::thread::scope(|s| {
        let handles: Vec<_> = groups
            .into_iter()
            .map(|g| s.spawn(move || g.into_iter().map(|j| j()).collect::<Vec<T>>()))
            .collect();
        // Join every worker before propagating, so a panic never unwinds
        // through the scope while other threads are running.
        let joined: Vec<std::thread::Result<Vec<T>>> =
            handles.into_iter().map(|h| h.join()).collect();
        joined
            .into_iter()
            .flat_map(|r| match r {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            })
            .collect()
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};
    use crate::psi::oracle_intersection;
    use crate::psi::sched::Pairing;
    use crate::util::check;

    fn fast_rsa() -> TpsiProtocol {
        TpsiProtocol::Rsa(super::super::rsa_psi::RsaPsiConfig {
            modulus_bits: 256,
            domain: "t".into(),
        })
    }

    fn run(sets: &[Vec<u64>], protocol: TpsiProtocol, pairing: Pairing) -> MpsiReport {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        let cfg = TreeMpsiConfig { protocol, pairing, seed: 11 };
        run_tree(sets, &cfg, &net, Parallel::new(4), &he).unwrap()
    }

    #[test]
    fn matches_oracle_rsa() {
        let sets = vec![
            vec![1, 2, 3, 4, 5, 6],
            vec![4, 5, 6, 7, 8],
            vec![5, 6, 4, 9],
            vec![6, 5, 4, 0],
        ];
        let r = run(&sets, fast_rsa(), Pairing::VolumeAware);
        assert_eq!(r.intersection, oracle_intersection(&sets));
    }

    #[test]
    fn matches_oracle_ot_many_clients() {
        check::forall(
            check::Config { cases: 12, seed: 3 },
            |rng| {
                let m = 2 + rng.below_usize(7);
                (0..m)
                    .map(|_| {
                        let n = 10 + rng.below_usize(40);
                        check::gen_index_set(rng, n, 80)
                    })
                    .collect::<Vec<_>>()
            },
            |sets| {
                let r = run(sets, TpsiProtocol::ot(), Pairing::VolumeAware);
                r.intersection == oracle_intersection(sets)
            },
        );
    }

    #[test]
    fn round_count_is_log_m() {
        for m in [2usize, 3, 4, 5, 8, 10, 16] {
            let sets: Vec<Vec<u64>> = (0..m).map(|_| (0..20).collect()).collect();
            let r = run(&sets, TpsiProtocol::ot(), Pairing::VolumeAware);
            let expect = (m as f64).log2().ceil() as usize;
            assert_eq!(r.num_rounds(), expect, "m={m}");
        }
    }

    #[test]
    fn single_client_short_circuits() {
        let r = run(&[vec![3, 1, 2]], TpsiProtocol::ot(), Pairing::VolumeAware);
        assert_eq!(r.intersection, vec![1, 2, 3]);
        assert_eq!(r.num_rounds(), 0);
    }

    #[test]
    fn request_order_also_correct() {
        let sets = vec![vec![1, 2, 3], vec![2, 3, 4], vec![3, 2, 9]];
        let r = run(&sets, fast_rsa(), Pairing::RequestOrder);
        assert_eq!(r.intersection, vec![2, 3]);
    }

    #[test]
    fn report_bytes_match_metered_bytes() {
        // The engine's own byte bookkeeping equals what the middleware
        // charged: nothing travels unmetered, nothing is double-counted.
        let sets: Vec<Vec<u64>> = (0..5).map(|c| (c..c + 30).collect()).collect();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        let cfg = TreeMpsiConfig { protocol: fast_rsa(), pairing: Pairing::VolumeAware, seed: 2 };
        let rep = run_tree(&sets, &cfg, &net, Parallel::serial(), &he).unwrap();
        assert_eq!(rep.total_bytes, meter.total_bytes("psi/"));
    }

    #[test]
    fn identical_result_and_bytes_at_any_worker_count() {
        // The worker budget is a pure perf knob for alignment too.
        let sets: Vec<Vec<u64>> = (0..6).map(|c| (c..c + 40).collect()).collect();
        let he = HeContext::for_tests();
        let run_with = |threads: usize| {
            let meter = Meter::new(NetConfig::lan_10gbps());
            let net = MeteredTransport::new(ChannelTransport::new(), &meter);
            let cfg =
                TreeMpsiConfig { protocol: fast_rsa(), pairing: Pairing::VolumeAware, seed: 7 };
            let rep = run_tree(&sets, &cfg, &net, Parallel::new(threads), &he).unwrap();
            (rep.intersection.clone(), rep.total_bytes)
        };
        let serial = run_with(1);
        for threads in [2usize, 4, 16] {
            assert_eq!(run_with(threads), serial, "threads={threads}");
        }
    }

    #[test]
    fn tree_makespan_beats_path_and_star() {
        // The Fig. 7 invariant: with many equal clients, Tree's simulated
        // distributed time is well below Path's and Star's (O(log m) rounds
        // of concurrent pairs vs O(m) serialized pairs).
        let sets: Vec<Vec<u64>> = (0..8).map(|_| (0..300).collect()).collect();
        let he = HeContext::for_tests();
        let cfg = TreeMpsiConfig {
            protocol: fast_rsa(),
            pairing: Pairing::VolumeAware,
            seed: 1,
        };
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        // Serial worker budget: each pair's wall-clock is measured
        // uncontended, which is what the max-over-pairs makespan model
        // assumes (one machine pair per TPSI in the paper's testbed).
        let tree = run_tree(&sets, &cfg, &net, Parallel::serial(), &he).unwrap();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let path =
            crate::psi::path::run_path(&sets, &fast_rsa(), 1, &net, Parallel::serial(), &he)
                .unwrap();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let star =
            crate::psi::star::run_star(&sets, &fast_rsa(), 0, 1, &net, Parallel::serial(), &he)
                .unwrap();
        assert!(
            tree.sim_s < path.sim_s * 0.7,
            "tree {} vs path {}",
            tree.sim_s,
            path.sim_s
        );
        assert!(
            tree.sim_s < star.sim_s * 0.7,
            "tree {} vs star {}",
            tree.sim_s,
            star.sim_s
        );
    }
}
