//! RSA-blind-signature two-party PSI (paper §4.1 primitive #1).
//!
//! Message flow — every arrow is an [`Envelope`](crate::net::Envelope)
//! through the [`Transport`], and the receiving side works from the
//! decoded wire bytes, never from shared memory:
//!
//! ```text
//!   sender                                   receiver
//!     | --- public key (n, e) ------------------> |
//!     | <-- blinded H(x)·r^e for each x --------- |   (receiver tx #1)
//!     | --- blind sigs + own sig keys ----------> |
//!     |                                            | unblind, compare
//! ```
//!
//! The receiver ends holding the intersection. Communication is
//! `|R|·k` receiver→sender and `|R|·k + 32·|S|` sender→receiver with k the
//! modulus width — the receiver's elements cross the wire twice, which is
//! exactly why the volume-aware scheduler makes the *smaller* party the
//! receiver for this protocol (paper's O(2|S|+|B|) optimization).

use crate::crypto::rsa::{signature_key, RsaKeyPair, RsaPublic};
use crate::error::Result;
use crate::net::{msg, Endpoint, PartyId, Transport};
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::{PairCost, TpsiOutcome};

/// RSA PSI parameters.
#[derive(Clone, Debug)]
pub struct RsaPsiConfig {
    /// Modulus size in bits. 512 by default: scaled down from a deployment
    /// 2048 so benchmark sweeps finish in minutes; the protocol's byte and
    /// round structure (what Fig. 7 compares) is unchanged.
    pub modulus_bits: usize,
    /// Domain-separation tag mixed into every indicator hash.
    pub domain: String,
}

impl Default for RsaPsiConfig {
    fn default() -> Self {
        RsaPsiConfig { modulus_bits: 512, domain: "treecss-rsa-psi".into() }
    }
}

/// Execute the protocol. See module docs for the message flow.
///
/// `par` bounds the workers the batch crypto (blinding, CRT signing) may
/// fan out over — results are bitwise invariant across worker counts, so
/// it is a pure perf knob (threaded down from `PipelineConfig::threads`).
#[allow(clippy::too_many_arguments)]
pub fn run(
    cfg: &RsaPsiConfig,
    sender: &[u64],
    receiver: &[u64],
    net: &dyn Transport,
    sender_id: PartyId,
    receiver_id: PartyId,
    phase: &str,
    seed: u64,
    par: Parallel,
) -> Result<TpsiOutcome> {
    let sw = Stopwatch::start();
    let mut rng = Rng::new(seed ^ 0x5A5A_1234);
    let mut sim_s = 0.0;
    let mut cost = PairCost::default();
    let snd = Endpoint::new(net, sender_id);
    let rcv = Endpoint::new(net, receiver_id);

    // --- sender: key generation + public key transfer -------------------
    let kp = RsaKeyPair::generate(&mut rng, cfg.modulus_bits)?;
    let pk_wire = msg::encode_public_key(&kp.public.n, &kp.public.e);
    cost.bytes_s2r += pk_wire.len() as u64;
    sim_s += snd.send(receiver_id, phase, pk_wire)?;

    // --- receiver: rebuild the key from the wire, blind, transmit --------
    let (n, e) = msg::decode_public_key(&rcv.recv(sender_id, phase)?.payload)?;
    if n.is_zero() || e.is_zero() {
        return Err(crate::Error::Net("malformed RSA public key on wire".into()));
    }
    let pk = RsaPublic::new(n, e);
    let width = pk.element_bytes();
    let blinded = pk.blind_batch(&mut rng, &cfg.domain, receiver, par);
    // Encode straight from the blinded values (no per-element clones).
    let blinded_wire =
        msg::encode_bigint_batch(blinded.iter().map(|b| &b.value), width);
    cost.bytes_r2s += blinded_wire.len() as u64;
    sim_s += rcv.send(sender_id, phase, blinded_wire)?;

    // --- sender: blind-sign receiver's elements; sign own set -----------
    let recv_blinded =
        msg::decode_bigint_batch(&snd.recv(receiver_id, phase)?.payload)?;
    let blind_sigs = kp.sign_batch(&recv_blinded, par);
    let own_keys: Vec<Vec<u8>> = kp
        .sign_indicator_batch(&cfg.domain, sender, par)
        .iter()
        .map(|sig| signature_key(sig).to_vec())
        .collect();
    // One logical message: the signed batch plus the sender's own keys.
    let mut reply = crate::util::codec::Encoder::new();
    reply
        .bytes(&msg::encode_bigint_batch(&blind_sigs, width))
        .bytes(&msg::encode_digest_batch(&own_keys));
    let reply = reply.finish();
    cost.bytes_s2r += reply.len() as u64;
    sim_s += snd.send(receiver_id, phase, reply)?;

    // --- receiver: unblind + compare -------------------------------------
    let reply = rcv.recv(sender_id, phase)?.payload;
    let mut d = crate::util::codec::Decoder::new(&reply);
    let sigs_wire = d.bytes().map_err(|e| crate::Error::Net(e.to_string()))?;
    let keys_wire = d.bytes().map_err(|e| crate::Error::Net(e.to_string()))?;
    d.finish().map_err(|e| crate::Error::Net(e.to_string()))?;
    let returned = msg::decode_bigint_batch(&sigs_wire)?;
    let mut sender_keys = std::collections::HashSet::new();
    for k in msg::decode_digest_batch(&keys_wire)? {
        let key: [u8; 32] = k
            .as_slice()
            .try_into()
            .map_err(|_| crate::Error::Net("malformed signature key on wire".into()))?;
        sender_keys.insert(key);
    }
    // Batch unblind: one modular inverse for the whole batch (§Perf).
    let unblinded = pk.unblind_batch(&blinded, &returned)?;
    let mut intersection = Vec::new();
    for (x, sig) in receiver.iter().zip(&unblinded) {
        if sender_keys.contains(&signature_key(sig)) {
            intersection.push(*x);
        }
    }
    intersection.sort_unstable();

    cost.sim_s = sim_s;
    cost.wall_s = sw.elapsed_secs();
    Ok(TpsiOutcome { intersection, cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};
    use crate::psi::oracle_intersection;

    fn fast_cfg() -> RsaPsiConfig {
        RsaPsiConfig { modulus_bits: 256, domain: "t".into() }
    }

    fn run_pair(s: &[u64], r: &[u64]) -> TpsiOutcome {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        run(
            &fast_cfg(),
            s,
            r,
            &net,
            PartyId::Client(0),
            PartyId::Client(1),
            "psi",
            42,
            Parallel::new(2),
        )
        .unwrap()
    }

    #[test]
    fn computes_exact_intersection() {
        let s = vec![1, 2, 3, 5, 8, 13, 21];
        let r = vec![2, 3, 4, 5, 6, 21, 100];
        let out = run_pair(&s, &r);
        assert_eq!(
            out.intersection,
            oracle_intersection(&[s.clone(), r.clone()])
        );
    }

    #[test]
    fn disjoint_and_identical_sets() {
        assert!(run_pair(&[1, 2], &[3, 4]).intersection.is_empty());
        assert_eq!(run_pair(&[7, 9], &[9, 7]).intersection, vec![7, 9]);
    }

    #[test]
    fn empty_sets() {
        assert!(run_pair(&[], &[1]).intersection.is_empty());
        assert!(run_pair(&[1], &[]).intersection.is_empty());
    }

    #[test]
    fn receiver_elements_cross_wire_twice() {
        // |R| >> |S|: r2s ≈ |R|·k, s2r ≈ |R|·k + 32|S| — so r2s and s2r are
        // both dominated by |R|. Swap roles and totals should drop.
        let big: Vec<u64> = (0..200).collect();
        let small: Vec<u64> = (0..20).collect();
        let big_as_receiver = run_pair(&small, &big).cost.total_bytes();
        let small_as_receiver = run_pair(&big, &small).cost.total_bytes();
        assert!(
            small_as_receiver < big_as_receiver,
            "small receiver {small_as_receiver} < big receiver {big_as_receiver}"
        );
    }

    #[test]
    fn meter_matches_cost_struct() {
        // Middleware accounting == the protocol's own bookkeeping: every
        // byte the pair believes it sent was charged on delivery.
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let out = run(
            &fast_cfg(),
            &[1, 2, 3],
            &[2, 3, 4],
            &net,
            PartyId::Client(0),
            PartyId::Client(1),
            "psi",
            7,
            Parallel::serial(),
        )
        .unwrap();
        assert_eq!(meter.total_bytes("psi"), out.cost.total_bytes());
    }

    #[test]
    fn wire_drains_completely() {
        let net = ChannelTransport::new();
        run(
            &fast_cfg(),
            &[1, 2],
            &[2, 5],
            &net,
            PartyId::Client(0),
            PartyId::Client(1),
            "psi",
            9,
            Parallel::serial(),
        )
        .unwrap();
        assert_eq!(net.pending(), 0, "protocol consumed every message");
    }

    #[test]
    fn deterministic_given_seed() {
        let a = run_pair(&[1, 2, 3], &[3, 4]);
        let b = run_pair(&[1, 2, 3], &[3, 4]);
        assert_eq!(a.intersection, b.intersection);
        assert_eq!(a.cost.total_bytes(), b.cost.total_bytes());
    }

    #[test]
    fn pair_is_bitwise_invariant_across_thread_budgets() {
        // The batch crypto plane is a pure perf knob: the pair's
        // intersection and its exact wire traffic are identical at any
        // worker count.
        let s: Vec<u64> = (0..40).collect();
        let r: Vec<u64> = (20..60).collect();
        let run_with = |threads: usize| {
            let meter = Meter::new(NetConfig::lan_10gbps());
            let net = MeteredTransport::new(ChannelTransport::new(), &meter);
            let out = run(
                &fast_cfg(),
                &s,
                &r,
                &net,
                PartyId::Client(0),
                PartyId::Client(1),
                "psi",
                13,
                Parallel::new(threads),
            )
            .unwrap();
            (out.intersection, out.cost.total_bytes(), meter.total_bytes("psi"))
        };
        let serial = run_with(1);
        for threads in [2usize, 4] {
            assert_eq!(run_with(threads), serial, "threads={threads}");
        }
    }
}
