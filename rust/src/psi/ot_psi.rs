//! OT/OPRF-based two-party PSI (paper §4.1 primitive #2, KKRT-style).
//!
//! Flow (costs modelled on OT-extension PSI, PRF evaluated for real):
//!
//! ```text
//!   sender                                    receiver
//!     | <-- base-OT setup + encodings --------- |   (fixed + |R|·enc bytes)
//!     |     [receiver obliviously obtains       |
//!     |      PRF_k(x) for its elements]         |
//!     | --- PRF_k(y) for every own y ---------> |   (|S|·mapped bytes)
//!     |                                          | compare
//! ```
//!
//! The receiver ends holding the intersection. The sender's mapped set uses
//! a larger per-element encoding (hash-to-bin + stash expansion in the real
//! protocol), so the volume-aware scheduler makes the *larger* party the
//! receiver — the opposite of the RSA rule, exactly as the paper states.
//!
//! The oblivious transfer itself is *simulated at the cost level*: we
//! evaluate PRF_k directly (the functionality) and ship
//! [`Envelope::sized`](crate::net::Envelope::sized) messages declaring the
//! bytes a KKRT-style instantiation would move (setup and encoding
//! envelopes carry no payload; the mapped set travels for real and the
//! receiver compares against the decoded wire bytes). Fig. 7(b) compares
//! topologies and scheduling, which depend on bytes × rounds — preserved
//! by this model.

use crate::crypto::prf::Prf;
use crate::error::Result;
use crate::net::{msg, Endpoint, PartyId, Transport};
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::{PairCost, TpsiOutcome};

/// OT-PSI cost/shape parameters.
#[derive(Clone, Debug)]
pub struct OtPsiConfig {
    /// One-time base-OT setup bytes (128 base OTs × 32 B, both directions).
    pub base_ot_bytes: u64,
    /// Per-receiver-element OT-extension encoding bytes (~2 × 16 B).
    pub recv_encoding_bytes: u64,
    /// Per-sender-element mapped-set bytes: 3 cuckoo hash functions × 16 B
    /// digests + bin/stash framing ≈ 96 B — the "large amount of data" the
    /// paper assigns to the sender, and why its rule makes the *larger*
    /// party the receiver for OT-based TPSI.
    pub send_mapped_bytes: u64,
}

impl Default for OtPsiConfig {
    fn default() -> Self {
        OtPsiConfig {
            base_ot_bytes: 128 * 32 * 2,
            recv_encoding_bytes: 32,
            send_mapped_bytes: 96,
        }
    }
}

/// Execute the protocol; intersection lands at the receiver. `par` bounds
/// the workers the PRF evaluation batches fan out over (pure perf knob;
/// results are bitwise invariant across worker counts).
#[allow(clippy::too_many_arguments)]
pub fn run(
    cfg: &OtPsiConfig,
    sender: &[u64],
    receiver: &[u64],
    net: &dyn Transport,
    sender_id: PartyId,
    receiver_id: PartyId,
    phase: &str,
    seed: u64,
    par: Parallel,
) -> Result<TpsiOutcome> {
    let sw = Stopwatch::start();
    let mut rng = Rng::new(seed ^ 0x07A9_C3D1_55B2_E600);
    let mut cost = PairCost::default();
    let mut sim_s = 0.0;
    let snd = Endpoint::new(net, sender_id);
    let rcv = Endpoint::new(net, receiver_id);

    // --- setup: base OTs (fixed), split across directions ----------------
    let half = cfg.base_ot_bytes / 2;
    sim_s += snd.send_sized(receiver_id, phase, Vec::new(), half)?;
    sim_s += rcv.send_sized(sender_id, phase, Vec::new(), half)?;
    rcv.recv(sender_id, phase)?;
    snd.recv(receiver_id, phase)?;
    cost.bytes_s2r += half;
    cost.bytes_r2s += half;

    // --- OPRF seed + receiver's oblivious evaluations --------------------
    let prf = Prf::random(&mut rng);
    // Receiver sends its OT-extension encodings (cost only; the
    // functionality result is PRF_k over receiver's elements).
    let recv_bytes = cfg.recv_encoding_bytes * receiver.len() as u64;
    sim_s += rcv.send_sized(sender_id, phase, Vec::new(), recv_bytes)?;
    snd.recv(receiver_id, phase)?;
    cost.bytes_r2s += recv_bytes;
    let recv_eval = prf.eval_batch_par(receiver, par);

    // --- sender transmits its mapped set ---------------------------------
    let sender_eval = prf.eval_batch_par(sender, par);
    let mapped: Vec<Vec<u8>> = sender_eval.iter().map(|d| d.to_vec()).collect();
    let wire = msg::encode_digest_batch(&mapped);
    // Declare the modelled per-element expansion rather than the raw digest
    // bytes (the real mapped set includes bin indices + stash).
    let mapped_bytes =
        (wire.len() as u64).max(cfg.send_mapped_bytes * sender.len() as u64);
    cost.bytes_s2r += mapped_bytes;
    sim_s += snd.send_sized(receiver_id, phase, wire, mapped_bytes)?;

    // --- receiver compares against the decoded wire bytes ----------------
    let mapped_wire = rcv.recv(sender_id, phase)?.payload;
    let mut sender_set = std::collections::HashSet::new();
    for d in msg::decode_digest_batch(&mapped_wire)? {
        let digest: [u8; 16] = d
            .as_slice()
            .try_into()
            .map_err(|_| crate::Error::Net("malformed mapped digest on wire".into()))?;
        sender_set.insert(digest);
    }
    let mut intersection: Vec<u64> = receiver
        .iter()
        .zip(&recv_eval)
        .filter(|(_, e)| sender_set.contains(*e))
        .map(|(&x, _)| x)
        .collect();
    intersection.sort_unstable();

    cost.sim_s = sim_s;
    cost.wall_s = sw.elapsed_secs();
    Ok(TpsiOutcome { intersection, cost })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};
    use crate::psi::oracle_intersection;
    use crate::util::check;

    fn run_pair(s: &[u64], r: &[u64]) -> TpsiOutcome {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        run(
            &OtPsiConfig::default(),
            s,
            r,
            &net,
            PartyId::Client(0),
            PartyId::Client(1),
            "psi",
            3,
            Parallel::new(2),
        )
        .unwrap()
    }

    #[test]
    fn computes_exact_intersection() {
        let s = vec![10, 20, 30, 40];
        let r = vec![40, 50, 10, 5];
        assert_eq!(
            run_pair(&s, &r).intersection,
            oracle_intersection(&[s.clone(), r.clone()])
        );
    }

    #[test]
    fn property_matches_oracle() {
        check::forall_default(
            |rng| {
                let n1 = 1 + rng.below_usize(60);
                let n2 = 1 + rng.below_usize(60);
                let a = check::gen_index_set(rng, n1, 120);
                let b = check::gen_index_set(rng, n2, 120);
                (a, b)
            },
            |(a, b)| {
                run_pair(a, b).intersection == oracle_intersection(&[a.clone(), b.clone()])
            },
        );
    }

    #[test]
    fn larger_receiver_is_cheaper() {
        // The paper's OT role rule: the sender transmits the expensive
        // mapped set (96 B/elem vs 32 B/elem for the receiver encodings),
        // so designating the *larger* party as receiver lowers total bytes.
        let big: Vec<u64> = (0..500).collect();
        let small: Vec<u64> = (0..50).collect();
        let big_as_sender = run_pair(&big, &small).cost.total_bytes();
        let big_as_receiver = run_pair(&small, &big).cost.total_bytes();
        assert!(
            big_as_receiver < big_as_sender,
            "{big_as_receiver} < {big_as_sender}"
        );
    }

    #[test]
    fn metered_bytes_match_cost_model() {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let out = run(
            &OtPsiConfig::default(),
            &[1, 2, 3, 4],
            &[3, 4, 5],
            &net,
            PartyId::Client(0),
            PartyId::Client(1),
            "psi",
            8,
            Parallel::serial(),
        )
        .unwrap();
        assert_eq!(meter.total_bytes("psi"), out.cost.total_bytes());
    }

    #[test]
    fn empty_sets_ok() {
        assert!(run_pair(&[], &[1, 2]).intersection.is_empty());
        assert!(run_pair(&[1, 2], &[]).intersection.is_empty());
    }
}
