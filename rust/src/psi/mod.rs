//! Private set intersection: two-party primitives and multi-party engines.
//!
//! Paper §4.1. The two-party primitives ([`rsa_psi`], [`ot_psi`]) execute
//! their cryptography for real and exchange every message through the
//! pluggable [`Transport`]; wrap the transport in
//! [`crate::net::MeteredTransport`] and every byte is charged to the
//! [`crate::net::Meter`] on delivery. Three MPSI engines compose them:
//!
//! * [`tree`] — **Tree-MPSI** (the paper's contribution): pairs active
//!   clients each round, runs the pairs concurrently, O(log m) rounds.
//! * [`path`] — Path-MPSI baseline: m−1 strictly sequential TPSIs.
//! * [`star`] — Star-MPSI baseline: a central client runs TPSI with every
//!   other client; O(1) logical rounds but the center serializes all
//!   bandwidth and compute.
//!
//! [`sched`] implements the data-volume-aware pairing optimization.

pub mod common;
pub mod ot_psi;
pub mod path;
pub mod rsa_psi;
pub mod sched;
pub mod star;
pub mod tree;

use crate::error::Result;
use crate::net::{PartyId, Transport};

/// Which two-party primitive an MPSI engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TpsiKind {
    /// RSA blind signatures (receiver should be the *smaller* party).
    Rsa,
    /// OT/OPRF-based (receiver should be the *larger* party).
    Ot,
}

/// Cost of one two-party PSI execution.
#[derive(Clone, Copy, Debug, Default)]
pub struct PairCost {
    /// Bytes sender -> receiver.
    pub bytes_s2r: u64,
    /// Bytes receiver -> sender.
    pub bytes_r2s: u64,
    /// Simulated transfer time of all pair messages (serialized per link).
    pub sim_s: f64,
    /// Measured wall-clock of the pair (crypto + comparison).
    pub wall_s: f64,
}

impl PairCost {
    pub fn total_bytes(&self) -> u64 {
        self.bytes_s2r + self.bytes_r2s
    }
}

/// Result of one two-party PSI: the intersection lands at the receiver.
#[derive(Clone, Debug)]
pub struct TpsiOutcome {
    pub intersection: Vec<u64>,
    pub cost: PairCost,
}

/// Two-party PSI protocol configuration (enum-dispatched).
#[derive(Clone, Debug)]
pub enum TpsiProtocol {
    Rsa(rsa_psi::RsaPsiConfig),
    Ot(ot_psi::OtPsiConfig),
}

impl TpsiProtocol {
    pub fn kind(&self) -> TpsiKind {
        match self {
            TpsiProtocol::Rsa(_) => TpsiKind::Rsa,
            TpsiProtocol::Ot(_) => TpsiKind::Ot,
        }
    }

    /// Default RSA config (512-bit modulus — scaled down from deployment
    /// 2048-bit for benchmark turnaround; same asymptotics, see DESIGN.md).
    pub fn rsa() -> Self {
        TpsiProtocol::Rsa(rsa_psi::RsaPsiConfig::default())
    }

    pub fn ot() -> Self {
        TpsiProtocol::Ot(ot_psi::OtPsiConfig::default())
    }

    /// Execute between `sender` and `receiver`; result at the receiver.
    ///
    /// `from`/`to` are the transport identities of sender/receiver;
    /// `phase` routes (and meters) the pair's messages; `seed` makes
    /// blinding deterministic per run; `par` bounds the workers the batch
    /// crypto fans out over (results are bitwise invariant across worker
    /// counts — a pure perf knob threaded from `PipelineConfig::threads`).
    #[allow(clippy::too_many_arguments)]
    pub fn run(
        &self,
        sender: &[u64],
        receiver: &[u64],
        net: &dyn Transport,
        from: PartyId,
        to: PartyId,
        phase: &str,
        seed: u64,
        par: crate::util::pool::Parallel,
    ) -> Result<TpsiOutcome> {
        match self {
            TpsiProtocol::Rsa(cfg) => {
                rsa_psi::run(cfg, sender, receiver, net, from, to, phase, seed, par)
            }
            TpsiProtocol::Ot(cfg) => {
                ot_psi::run(cfg, sender, receiver, net, from, to, phase, seed, par)
            }
        }
    }
}

/// Per-round accounting from an MPSI engine.
#[derive(Clone, Debug, Default)]
pub struct RoundReport {
    /// (sender, receiver, |result|) per pair in the round.
    pub pairs: Vec<(u32, u32, usize)>,
    /// Simulated *distributed makespan* of the round: each pair's measured
    /// crypto compute + its wire time, combined per-topology (max over a
    /// Tree round's concurrent pairs; sums where a party serializes). This
    /// models the paper's testbed — one machine per party — on a
    /// single-core host (pairs here share one CPU, so local wall-clock
    /// cannot exhibit the parallelism the protocol creates).
    pub sim_s: f64,
    /// Local wall-clock of the round on this host.
    pub wall_s: f64,
    pub bytes: u64,
}

/// Result of a full multi-party PSI execution.
#[derive(Clone, Debug)]
pub struct MpsiReport {
    /// The aligned sample indicators, ascending.
    pub intersection: Vec<u64>,
    pub rounds: Vec<RoundReport>,
    /// Total local wall-clock including scheduling + result allocation.
    pub wall_s: f64,
    /// Simulated distributed end-to-end time (compute + wire, see
    /// [`RoundReport::sim_s`]) — the Fig. 7 comparison metric.
    pub sim_s: f64,
    pub total_bytes: u64,
}

impl MpsiReport {
    pub fn num_rounds(&self) -> usize {
        self.rounds.len()
    }
}

/// Oracle intersection for tests/benches: multi-set intersection of all
/// client sets, sorted ascending.
pub fn oracle_intersection(sets: &[Vec<u64>]) -> Vec<u64> {
    if sets.is_empty() {
        return vec![];
    }
    let mut acc: std::collections::HashSet<u64> = sets[0].iter().copied().collect();
    for s in &sets[1..] {
        let next: std::collections::HashSet<u64> = s.iter().copied().collect();
        acc = acc.intersection(&next).copied().collect();
    }
    let mut v: Vec<u64> = acc.into_iter().collect();
    v.sort_unstable();
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn oracle_basics() {
        let sets = vec![vec![1, 2, 3, 4], vec![2, 4, 6], vec![4, 2, 0]];
        assert_eq!(oracle_intersection(&sets), vec![2, 4]);
        assert_eq!(oracle_intersection(&[]), Vec::<u64>::new());
        assert_eq!(oracle_intersection(&[vec![5, 1]]), vec![1, 5]);
    }
}
