//! Star-MPSI baseline: a central participant runs two-party PSI with every
//! other client and intersects the results locally.
//!
//! O(1) logical rounds, but the center's NIC and CPU serialize all m−1
//! exchanges — the paper's "high communication bandwidth and computation
//! power for the central participant ... may become the bottleneck".
//! We model that bottleneck faithfully: spoke TPSIs run sequentially at the
//! center, and their simulated times are summed.

use crate::error::Result;
use crate::net::{PartyId, Transport};
use crate::util::pool::Parallel;
use crate::util::rng::Rng;
use crate::util::timer::Stopwatch;

use super::common::{allocate_result, HeContext};
use super::tree::derive_seed;
use super::{MpsiReport, RoundReport, TpsiProtocol};

/// Run Star-MPSI with `center` as the hub (client index). Spoke TPSIs
/// serialize at the center, so each spoke's batch crypto gets the whole
/// `par` budget.
pub fn run_star(
    sets: &[Vec<u64>],
    protocol: &TpsiProtocol,
    center: usize,
    seed: u64,
    net: &dyn Transport,
    par: Parallel,
    he: &HeContext,
) -> Result<MpsiReport> {
    assert!(!sets.is_empty());
    assert!(center < sets.len());
    let total_sw = Stopwatch::start();
    let m = sets.len();
    let mut result = sets[center].clone();
    let mut round = RoundReport::default();
    let mut sim_total = 0.0;
    let mut total_bytes = 0u64;

    for spoke in 0..m {
        if spoke == center {
            continue;
        }
        let phase = format!("psi/spoke{spoke}");
        // Spoke is the sender; the center receives and keeps the running
        // intersection (it must, to intersect across all spokes).
        let out = protocol.run(
            &sets[spoke],
            &result,
            net,
            PartyId::Client(spoke as u32),
            PartyId::Client(center as u32),
            &phase,
            derive_seed(seed, spoke as u32, 1),
            par,
        )?;
        round.pairs.push((spoke as u32, center as u32, out.intersection.len()));
        round.bytes += out.cost.total_bytes();
        // The center participates in (and its running result feeds) every
        // spoke TPSI, so compute + wire serialize at the hub: sum, not max.
        round.sim_s += out.cost.sim_s + out.cost.wall_s;
        result = out.intersection;
    }
    round.wall_s = total_sw.elapsed_secs();
    sim_total += round.sim_s;
    total_bytes += round.bytes;

    result.sort_unstable();
    let mut rng = Rng::new(seed ^ 0xCAFE);
    let alloc = allocate_result(
        center as u32,
        m as u32,
        &result,
        he,
        net,
        "psi/alloc",
        &mut rng,
        par,
    )?;
    sim_total += alloc.sim_s;
    total_bytes += alloc.bytes;

    Ok(MpsiReport {
        intersection: result,
        total_bytes,
        rounds: vec![round],
        wall_s: total_sw.elapsed_secs(),
        sim_s: sim_total,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};
    use crate::psi::oracle_intersection;

    fn run(sets: &[Vec<u64>], center: usize) -> MpsiReport {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        run_star(sets, &TpsiProtocol::ot(), center, 9, &net, Parallel::new(2), &he).unwrap()
    }

    #[test]
    fn matches_oracle_any_center() {
        let sets = vec![
            vec![1, 2, 3, 4, 9],
            vec![2, 3, 4, 5],
            vec![2, 4, 5, 6, 3],
            vec![4, 3, 2, 1],
        ];
        for center in 0..sets.len() {
            assert_eq!(
                run(&sets, center).intersection,
                oracle_intersection(&sets),
                "center={center}"
            );
        }
    }

    #[test]
    fn one_logical_round() {
        let sets: Vec<Vec<u64>> = (0..6).map(|_| (0..10).collect()).collect();
        let r = run(&sets, 0);
        assert_eq!(r.num_rounds(), 1);
        assert_eq!(r.rounds[0].pairs.len(), 5);
    }

    #[test]
    fn center_carries_most_bytes() {
        let sets: Vec<Vec<u64>> = (0..5).map(|_| (0..200).collect()).collect();
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        run_star(&sets, &TpsiProtocol::ot(), 0, 9, &net, Parallel::serial(), &he).unwrap();
        let center_bytes = meter.party_bytes(PartyId::Client(0), "psi/spoke");
        for spoke in 1..5u32 {
            let b = meter.party_bytes(PartyId::Client(spoke), "psi/spoke");
            assert!(center_bytes > b, "center {center_bytes} > spoke{spoke} {b}");
        }
    }
}
