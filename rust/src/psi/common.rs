//! Shared pieces of the MPSI engines: the HE context from the key server
//! and the result-allocation step (paper Fig. 2 steps 5–6).

use std::sync::Arc;

use crate::crypto::paillier::{self, PaillierPrivate, PaillierPublic};
use crate::net::msg::{self, HybridEnvelope};
use crate::net::{Meter, PartyId};
use crate::util::rng::Rng;

/// HE key material distributed by the key server. The aggregation server
/// never holds `sk` — it only routes sealed envelopes.
#[derive(Clone)]
pub struct HeContext {
    pub pk: PaillierPublic,
    sk: Arc<PaillierPrivate>,
}

impl HeContext {
    /// Generate a context (one per experiment; 512-bit default).
    pub fn generate(rng: &mut Rng, bits: usize) -> Self {
        let (pk, sk) = paillier::keygen(rng, bits).expect("paillier keygen");
        HeContext { pk, sk: Arc::new(sk) }
    }

    /// Fast context for tests.
    pub fn for_tests() -> Self {
        Self::generate(&mut Rng::new(0xDECAF), 256)
    }

    pub fn private(&self) -> &PaillierPrivate {
        &self.sk
    }
}

/// Result allocation: the final holder seals the aligned, ordered indicator
/// list under HE and ships it to every other client via the aggregation
/// server. Returns the simulated time of the step.
pub fn allocate_result(
    holder: u32,
    num_clients: u32,
    result: &[u64],
    he: &HeContext,
    meter: &Meter,
    phase: &str,
    rng: &mut Rng,
) -> f64 {
    let payload = msg::encode_index_list(result);
    let env = HybridEnvelope::seal(rng, &he.pk, &payload).expect("seal");
    let wire = env.encode();
    let mut sim = meter.charge(
        PartyId::Client(holder),
        PartyId::Aggregator,
        phase,
        wire.len() as u64,
    );
    // The aggregator forwards to every other client; its uplink serializes.
    for c in 0..num_clients {
        if c == holder {
            continue;
        }
        sim += meter.charge(PartyId::Aggregator, PartyId::Client(c), phase, wire.len() as u64);
    }
    // Every client can decrypt with the key-server-provided private key.
    let opened = env.open(he.private()).expect("open");
    debug_assert_eq!(msg::decode_index_list(&opened).unwrap(), result);
    sim
}

/// Per-round scheduling chatter: each active client requests (step 1),
/// the aggregator answers with a status message (step 3). Returns sim time
/// (serialized at the aggregator, which is the paper's design).
pub fn charge_round_scheduling(
    active: &[(usize, u64)],
    round: u32,
    meter: &Meter,
    phase: &str,
) -> f64 {
    let mut sim = 0.0;
    for &(id, res_len) in active {
        let req = msg::PsiRequest { client: id as u32, res_len, has_result: round > 0 };
        sim += meter.charge(
            PartyId::Client(id as u32),
            PartyId::Aggregator,
            phase,
            req.encode().len() as u64,
        );
        let status = msg::PsiSchedule { round, partner: Some(0), is_receiver: false };
        sim += meter.charge(
            PartyId::Aggregator,
            PartyId::Client(id as u32),
            phase,
            status.encode().len() as u64,
        );
    }
    sim
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::NetConfig;

    #[test]
    fn allocation_charges_m_minus_1_forwards() {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let he = HeContext::for_tests();
        let mut rng = Rng::new(5);
        let sim = allocate_result(2, 5, &[1, 2, 3], &he, &meter, "alloc", &mut rng);
        assert!(sim > 0.0);
        // 1 upload + 4 forwards.
        assert_eq!(meter.total_messages("alloc"), 5);
    }

    #[test]
    fn scheduling_charges_two_messages_per_client() {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let active = [(0usize, 10u64), (1, 20), (2, 30)];
        charge_round_scheduling(&active, 0, &meter, "sched");
        assert_eq!(meter.total_messages("sched"), 6);
    }
}
