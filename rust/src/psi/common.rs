//! Shared pieces of the MPSI engines: the HE context from the key server,
//! the round-scheduling exchange (paper Fig. 2 steps 1–3) and the
//! result-allocation step (steps 5–6) — all message-passing over the
//! [`Transport`], so the engines' wire traffic is exactly what a
//! per-process deployment would send.

use std::sync::Arc;

use crate::crypto::paillier::{self, PaillierPrivate, PaillierPublic};
use crate::error::Result;
use crate::net::msg::{self, HybridEnvelope, PsiRequest, PsiSchedule};
use crate::net::{Endpoint, PartyId, Transport};
use crate::util::pool::Parallel;
use crate::util::rng::Rng;

use super::sched::{schedule, Pairing, RoundSchedule, ScheduledPair};
use super::TpsiKind;

/// HE key material distributed by the key server. The aggregation server
/// never holds `sk` — it only routes sealed envelopes.
#[derive(Clone)]
pub struct HeContext {
    pub pk: PaillierPublic,
    sk: Arc<PaillierPrivate>,
}

impl HeContext {
    /// Generate a context (one per experiment; 512-bit default).
    pub fn generate(rng: &mut Rng, bits: usize) -> Self {
        let (pk, sk) = paillier::keygen(rng, bits).expect("paillier keygen");
        HeContext { pk, sk: Arc::new(sk) }
    }

    /// Fast context for tests.
    pub fn for_tests() -> Self {
        Self::generate(&mut Rng::new(0xDECAF), 256)
    }

    pub fn private(&self) -> &PaillierPrivate {
        &self.sk
    }
}

/// Wire traffic summary of a protocol step: simulated transfer time plus
/// the bytes that crossed the transport (the engine's own bookkeeping;
/// the authoritative per-edge record lives in the metering middleware).
#[derive(Clone, Copy, Debug, Default)]
pub struct Flow {
    pub sim_s: f64,
    pub bytes: u64,
}

impl Flow {
    pub fn add(&mut self, sim_s: f64, bytes: u64) {
        self.sim_s += sim_s;
        self.bytes += bytes;
    }
}

/// Result allocation: the final holder seals the aligned, ordered indicator
/// list under HE and ships it to every other client via the aggregation
/// server, which routes ciphertext it cannot open. `par` bounds the
/// envelope's Paillier batch workers (thread-count-invariant).
#[allow(clippy::too_many_arguments)]
pub fn allocate_result(
    holder: u32,
    num_clients: u32,
    result: &[u64],
    he: &HeContext,
    net: &dyn Transport,
    phase: &str,
    rng: &mut Rng,
    par: Parallel,
) -> Result<Flow> {
    let mut flow = Flow::default();
    let payload = msg::encode_index_list(result);
    let env = HybridEnvelope::seal(rng, &he.pk, &payload, par)?;
    let wire = env.encode();

    // Holder uploads the sealed result to the aggregator.
    let holder_ep = Endpoint::new(net, PartyId::Client(holder));
    flow.add(
        holder_ep.send(PartyId::Aggregator, phase, wire.clone())?,
        wire.len() as u64,
    );

    // The aggregator forwards the (opaque) envelope to every other client;
    // its uplink serializes.
    let agg = Endpoint::new(net, PartyId::Aggregator);
    let routed = agg.recv(PartyId::Client(holder), phase)?;
    for c in 0..num_clients {
        if c == holder {
            continue;
        }
        flow.add(
            agg.send(PartyId::Client(c), phase, routed.payload.clone())?,
            routed.payload.len() as u64,
        );
    }

    // Every client opens its delivery with the key-server-provided private
    // key and recovers the aligned indicator list from the wire bytes.
    for c in 0..num_clients {
        if c == holder {
            continue;
        }
        let delivered = Endpoint::new(net, PartyId::Client(c))
            .recv(PartyId::Aggregator, phase)?;
        let sealed = HybridEnvelope::decode(&delivered.payload)?;
        let opened = sealed.open(he.private(), par)?;
        if msg::decode_index_list(&opened)? != result {
            return Err(crate::Error::Psi(format!(
                "client {c}: allocated result corrupted in transit"
            )));
        }
    }
    Ok(flow)
}

/// Client side of alignment step 1: announce "am I active, and how many
/// items do I hold" to the aggregation server.
pub fn announce(
    net: &dyn Transport,
    client: u32,
    res_len: u64,
    round: u32,
    phase: &str,
) -> Result<Flow> {
    let req = PsiRequest { client, res_len, has_result: round > 0 };
    let wire = req.encode();
    let bytes = wire.len() as u64;
    let sim =
        Endpoint::new(net, PartyId::Client(client)).send(PartyId::Aggregator, phase, wire)?;
    Ok(Flow { sim_s: sim, bytes })
}

/// Client side of alignment step 3: block for the aggregator's status
/// message naming this round's partner and role.
pub fn await_schedule(net: &dyn Transport, client: u32, phase: &str) -> Result<PsiSchedule> {
    let env = Endpoint::new(net, PartyId::Client(client)).recv(PartyId::Aggregator, phase)?;
    PsiSchedule::decode(&env.payload)
}

/// The full round-scheduling exchange (paper Fig. 2 steps 1–3), with the
/// party halves interleaved deadlock-free: every active client announces,
/// the aggregator collects the requests *from the wire*, pairs the clients
/// it heard from, and answers each with its partner and role; the returned
/// plan is rebuilt from the schedules the clients actually decoded — the
/// request/status messages are load-bearing, not decorative.
pub fn exchange_round_schedule(
    active: &[(usize, u64)],
    round: u32,
    pairing: Pairing,
    kind: TpsiKind,
    net: &dyn Transport,
    phase: &str,
) -> Result<(RoundSchedule, Flow)> {
    let mut flow = Flow::default();

    // Step 1: clients announce.
    for &(id, res_len) in active {
        let f = announce(net, id as u32, res_len, round, phase)?;
        flow.add(f.sim_s, f.bytes);
    }

    // Step 2: the aggregator rebuilds the active list from its mailbox and
    // runs the pairing strategy on what it received.
    let agg = Endpoint::new(net, PartyId::Aggregator);
    let mut heard = Vec::with_capacity(active.len());
    for &(id, _) in active {
        let env = agg.recv(PartyId::Client(id as u32), phase)?;
        let req = PsiRequest::decode(&env.payload)?;
        heard.push((req.client as usize, req.res_len));
    }
    let plan = schedule(&heard, pairing, kind);

    // Step 3: the aggregator answers every client.
    for &(id, _) in active {
        let status = status_for(&plan, id, round);
        let wire = status.encode();
        flow.add(
            agg.send(PartyId::Client(id as u32), phase, wire.clone())?,
            wire.len() as u64,
        );
    }

    // Clients decode their status; the engine's plan is whatever traveled.
    let mut pairs = Vec::new();
    let mut bye = None;
    for &(id, _) in active {
        let status = await_schedule(net, id as u32, phase)?;
        match status.partner {
            None => bye = Some(id),
            Some(p) if status.is_receiver => {
                pairs.push(ScheduledPair { sender: p as usize, receiver: id })
            }
            Some(_) => {} // sender role: recorded by the partner's status
        }
    }
    Ok((RoundSchedule { pairs, bye }, flow))
}

/// The status message for one client under a round plan.
fn status_for(plan: &RoundSchedule, id: usize, round: u32) -> PsiSchedule {
    for p in &plan.pairs {
        if p.sender == id {
            return PsiSchedule { round, partner: Some(p.receiver as u32), is_receiver: false };
        }
        if p.receiver == id {
            return PsiSchedule { round, partner: Some(p.sender as u32), is_receiver: true };
        }
    }
    // Not paired this round: wait (odd one out).
    PsiSchedule { round, partner: None, is_receiver: false }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::{ChannelTransport, Meter, MeteredTransport, NetConfig};

    #[test]
    fn allocation_charges_m_minus_1_forwards() {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let he = HeContext::for_tests();
        let mut rng = Rng::new(5);
        let flow =
            allocate_result(2, 5, &[1, 2, 3], &he, &net, "alloc", &mut rng, Parallel::new(2))
                .unwrap();
        assert!(flow.sim_s > 0.0);
        // 1 upload + 4 forwards, both in the meter and in the engine flow.
        assert_eq!(meter.total_messages("alloc"), 5);
        assert_eq!(meter.total_bytes("alloc"), flow.bytes);
        // Every byte transits the aggregator (the routing privacy shape).
        assert_eq!(meter.party_bytes(PartyId::Aggregator, "alloc"), flow.bytes);
    }

    #[test]
    fn scheduling_messages_travel_and_rebuild_the_plan() {
        let meter = Meter::new(NetConfig::lan_10gbps());
        let net = MeteredTransport::new(ChannelTransport::new(), &meter);
        let active = [(0usize, 10u64), (1, 20), (2, 30), (3, 40)];
        let (plan, flow) = exchange_round_schedule(
            &active,
            0,
            Pairing::VolumeAware,
            TpsiKind::Rsa,
            &net,
            "sched",
        )
        .unwrap();
        // Two messages per active client: request up, status down.
        assert_eq!(meter.total_messages("sched"), 8);
        assert_eq!(meter.total_bytes("sched"), flow.bytes);
        // The traveled plan matches the pairing strategy run directly.
        let direct = schedule(&active, Pairing::VolumeAware, TpsiKind::Rsa);
        let mut got = plan.pairs.clone();
        let mut want = direct.pairs.clone();
        got.sort_by_key(|p| p.receiver);
        want.sort_by_key(|p| p.receiver);
        assert_eq!(got, want);
        assert_eq!(plan.bye, direct.bye);
    }

    #[test]
    fn odd_client_count_byes_over_the_wire() {
        let net = ChannelTransport::new();
        let active = [(4usize, 9u64), (7, 9), (9, 9)];
        let (plan, _) = exchange_round_schedule(
            &active,
            1,
            Pairing::RequestOrder,
            TpsiKind::Ot,
            &net,
            "s",
        )
        .unwrap();
        assert_eq!(plan.pairs.len(), 1);
        assert!(plan.bye.is_some());
        assert_eq!(net.pending(), 0);
    }
}
