//! Data substrate: dense matrices, datasets, vertical partitioning, CSV
//! I/O, and the synthetic generators standing in for the paper's six
//! Kaggle/UCI datasets (no network on this image — see DESIGN.md).

pub mod csv;
pub mod dataset;
pub mod matrix;
pub mod synth;

pub use dataset::{Dataset, Task, VerticalPartition};
pub use matrix::Matrix;
