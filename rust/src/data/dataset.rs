//! Dataset container and vertical partitioning.
//!
//! A [`Dataset`] is the *logical* global table (features + labels + global
//! sample indicators). [`VerticalPartition`] splits its feature columns
//! across M clients — the VFL data layout of the paper, where every client
//! sees all samples but only its own feature slice, and only the label
//! owner sees labels.

use crate::error::{Error, Result};
use crate::util::rng::Rng;

use super::matrix::Matrix;

/// Learning task kind.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Task {
    /// Classification with `n_classes` classes (labels 0..n).
    Classification { n_classes: usize },
    /// Scalar regression.
    Regression,
}

impl Task {
    pub fn n_classes(&self) -> usize {
        match self {
            Task::Classification { n_classes } => *n_classes,
            Task::Regression => 0,
        }
    }

    pub fn is_classification(&self) -> bool {
        matches!(self, Task::Classification { .. })
    }
}

/// A supervised dataset with global sample indicators.
#[derive(Clone, Debug)]
pub struct Dataset {
    /// N × d feature matrix.
    pub x: Matrix,
    /// N labels (class index as f32, or regression target).
    pub y: Vec<f32>,
    /// Global sample indicators (what PSI aligns on).
    pub ids: Vec<u64>,
    pub task: Task,
    pub name: String,
}

impl Dataset {
    pub fn new(name: &str, x: Matrix, y: Vec<f32>, task: Task) -> Result<Self> {
        if x.rows() != y.len() {
            return Err(Error::Data(format!(
                "{name}: {} rows vs {} labels",
                x.rows(),
                y.len()
            )));
        }
        let ids = (0..x.rows() as u64).collect();
        Ok(Dataset { x, y, ids, task, name: name.into() })
    }

    pub fn n(&self) -> usize {
        self.x.rows()
    }

    pub fn d(&self) -> usize {
        self.x.cols()
    }

    /// Train/test split by shuffled index (fraction in (0,1)).
    pub fn split(&self, train_frac: f64, rng: &mut Rng) -> (Dataset, Dataset) {
        let n = self.n();
        let mut idx: Vec<usize> = (0..n).collect();
        rng.shuffle(&mut idx);
        let n_train = ((n as f64) * train_frac).round() as usize;
        let (tr, te) = idx.split_at(n_train.clamp(1, n - 1));
        (self.subset(tr), self.subset(te))
    }

    /// Row subset (keeps global ids).
    pub fn subset(&self, idx: &[usize]) -> Dataset {
        Dataset {
            x: self.x.select_rows(idx),
            y: idx.iter().map(|&i| self.y[i]).collect(),
            ids: idx.iter().map(|&i| self.ids[i]).collect(),
            task: self.task,
            name: self.name.clone(),
        }
    }

    /// Subset by global indicator list (the PSI result).
    pub fn subset_by_ids(&self, ids: &[u64]) -> Dataset {
        let pos: std::collections::HashMap<u64, usize> =
            self.ids.iter().enumerate().map(|(i, &id)| (id, i)).collect();
        let idx: Vec<usize> = ids.iter().filter_map(|id| pos.get(id).copied()).collect();
        self.subset(&idx)
    }

    /// Standardize features in place (per column).
    pub fn standardize(&mut self) {
        self.x.standardize();
    }

    /// One-hot encode labels (classification only).
    pub fn one_hot(&self) -> Result<Matrix> {
        let k = self.task.n_classes();
        if k == 0 {
            return Err(Error::Data("one_hot on regression task".into()));
        }
        let mut m = Matrix::zeros(self.n(), k);
        for (r, &y) in self.y.iter().enumerate() {
            let c = y as usize;
            if c >= k {
                return Err(Error::Data(format!("label {c} out of range {k}")));
            }
            m.set(r, c, 1.0);
        }
        Ok(m)
    }
}

/// Feature columns split across M clients.
#[derive(Clone, Debug)]
pub struct VerticalPartition {
    /// Per-client column ranges [lo, hi) into the global feature matrix.
    pub ranges: Vec<(usize, usize)>,
}

impl VerticalPartition {
    /// Split `d` columns as evenly as possible across `m` clients
    /// (the paper's protocol: "equally partitioned into three portions").
    pub fn even(d: usize, m: usize) -> Self {
        assert!(m >= 1 && d >= m, "need at least one column per client");
        let base = d / m;
        let extra = d % m;
        let mut ranges = Vec::with_capacity(m);
        let mut lo = 0;
        for i in 0..m {
            let w = base + usize::from(i < extra);
            ranges.push((lo, lo + w));
            lo += w;
        }
        Self { ranges }
    }

    pub fn num_clients(&self) -> usize {
        self.ranges.len()
    }

    /// Client m's feature slice of `x`.
    pub fn slice(&self, x: &Matrix, client: usize) -> Matrix {
        let (lo, hi) = self.ranges[client];
        x.select_cols(lo, hi)
    }

    /// Width of client m's slice.
    pub fn width(&self, client: usize) -> usize {
        let (lo, hi) = self.ranges[client];
        hi - lo
    }

    /// Max client width (drives artifact Dm selection).
    pub fn max_width(&self) -> usize {
        (0..self.num_clients()).map(|c| self.width(c)).max().unwrap_or(0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toy() -> Dataset {
        let x = Matrix::from_fn(10, 7, |r, c| (r * 7 + c) as f32);
        let y = (0..10).map(|i| (i % 2) as f32).collect();
        Dataset::new("toy", x, y, Task::Classification { n_classes: 2 }).unwrap()
    }

    #[test]
    fn split_partitions_rows() {
        let d = toy();
        let mut rng = Rng::new(1);
        let (tr, te) = d.split(0.7, &mut rng);
        assert_eq!(tr.n() + te.n(), 10);
        assert_eq!(tr.n(), 7);
        let mut ids: Vec<u64> = tr.ids.iter().chain(&te.ids).copied().collect();
        ids.sort_unstable();
        assert_eq!(ids, (0..10).collect::<Vec<u64>>());
    }

    #[test]
    fn subset_by_ids_aligns() {
        let d = toy();
        let s = d.subset_by_ids(&[3, 7, 1]);
        assert_eq!(s.ids, vec![3, 7, 1]);
        assert_eq!(s.x.get(0, 0), d.x.get(3, 0));
        assert_eq!(s.y[2], d.y[1]);
    }

    #[test]
    fn one_hot_valid() {
        let d = toy();
        let oh = d.one_hot().unwrap();
        assert_eq!(oh.shape(), (10, 2));
        for r in 0..10 {
            assert_eq!(oh.row(r).iter().sum::<f32>(), 1.0);
            assert_eq!(oh.get(r, d.y[r] as usize), 1.0);
        }
    }

    #[test]
    fn even_partition_covers_all_columns() {
        for (d, m) in [(7usize, 3usize), (11, 3), (12, 4), (5, 5), (90, 3)] {
            let p = VerticalPartition::even(d, m);
            assert_eq!(p.num_clients(), m);
            assert_eq!(p.ranges[0].0, 0);
            assert_eq!(p.ranges[m - 1].1, d);
            for w in 0..m - 1 {
                assert_eq!(p.ranges[w].1, p.ranges[w + 1].0, "contiguous");
            }
            // widths differ by at most 1
            let ws: Vec<usize> = (0..m).map(|c| p.width(c)).collect();
            assert!(ws.iter().max().unwrap() - ws.iter().min().unwrap() <= 1);
        }
    }

    #[test]
    fn slice_extracts_right_columns() {
        let d = toy();
        let p = VerticalPartition::even(7, 3); // widths 3,2,2
        let s1 = p.slice(&d.x, 1);
        assert_eq!(s1.shape(), (10, 2));
        assert_eq!(s1.get(0, 0), d.x.get(0, 3));
    }

    #[test]
    fn label_out_of_range_rejected() {
        let x = Matrix::zeros(2, 2);
        let d = Dataset::new("bad", x, vec![0.0, 5.0], Task::Classification { n_classes: 2 })
            .unwrap();
        assert!(d.one_hot().is_err());
    }
}
