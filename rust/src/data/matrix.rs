//! Dense row-major f32 matrix.
//!
//! The XLA artifacts carry the heavy matmuls on the training path; this
//! type exists for data plumbing, the pure-Rust reference models (used in
//! parity tests and as a no-artifact fallback), K-Means bookkeeping, and
//! the V-coreset baseline. The matmul is cache-blocked since the fallback
//! path uses it in inner loops, and the `*_par` variants chunk output rows
//! across a [`Parallel`] worker set — per-row accumulation order is
//! unchanged, so results are bitwise identical at any thread count.

use crate::error::{Error, Result};
use crate::util::pool::{concat_chunks, Parallel};

/// Row-major dense matrix of f32.
#[derive(Clone, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f32>,
}

impl std::fmt::Debug for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "Matrix({}x{})", self.rows, self.cols)
    }
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn from_vec(rows: usize, cols: usize, data: Vec<f32>) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::Data(format!(
                "shape {rows}x{cols} needs {} values, got {}",
                rows * cols,
                data.len()
            )));
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Build from a row-generator closure.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f32) -> Self {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Matrix { rows, cols, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn shape(&self) -> (usize, usize) {
        (self.rows, self.cols)
    }

    pub fn data(&self) -> &[f32] {
        &self.data
    }

    pub fn data_mut(&mut self) -> &mut [f32] {
        &mut self.data
    }

    pub fn into_vec(self) -> Vec<f32> {
        self.data
    }

    #[inline]
    pub fn get(&self, r: usize, c: usize) -> f32 {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c]
    }

    #[inline]
    pub fn set(&mut self, r: usize, c: usize, v: f32) {
        debug_assert!(r < self.rows && c < self.cols);
        self.data[r * self.cols + c] = v;
    }

    pub fn row(&self, r: usize) -> &[f32] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Select a subset of rows (gather).
    pub fn select_rows(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &r in idx {
            data.extend_from_slice(self.row(r));
        }
        Matrix { rows: idx.len(), cols: self.cols, data }
    }

    /// Select a contiguous column range [lo, hi).
    pub fn select_cols(&self, lo: usize, hi: usize) -> Matrix {
        assert!(lo <= hi && hi <= self.cols);
        let w = hi - lo;
        let mut data = Vec::with_capacity(self.rows * w);
        for r in 0..self.rows {
            data.extend_from_slice(&self.row(r)[lo..hi]);
        }
        Matrix { rows: self.rows, cols: w, data }
    }

    /// Horizontal concatenation.
    pub fn hcat(parts: &[&Matrix]) -> Result<Matrix> {
        if parts.is_empty() {
            return Err(Error::Data("hcat of nothing".into()));
        }
        let rows = parts[0].rows;
        if parts.iter().any(|p| p.rows != rows) {
            return Err(Error::Data("hcat row mismatch".into()));
        }
        let cols: usize = parts.iter().map(|p| p.cols).sum();
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for p in parts {
                data.extend_from_slice(p.row(r));
            }
        }
        Ok(Matrix { rows, cols, data })
    }

    /// Pad with zero columns on the right to reach `cols` (XLA artifacts
    /// have static widths; padded weight columns provably get zero grads).
    pub fn pad_cols(&self, cols: usize) -> Matrix {
        assert!(cols >= self.cols);
        let mut out = Matrix::zeros(self.rows, cols);
        for r in 0..self.rows {
            out.row_mut(r)[..self.cols].copy_from_slice(self.row(r));
        }
        out
    }

    /// Pad with zero rows at the bottom to reach `rows`.
    pub fn pad_rows(&self, rows: usize) -> Matrix {
        assert!(rows >= self.rows);
        let mut data = self.data.clone();
        data.resize(rows * self.cols, 0.0);
        Matrix { rows, cols: self.cols, data }
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for r in 0..self.rows {
            for c in 0..self.cols {
                out.data[c * self.rows + r] = self.data[r * self.cols + c];
            }
        }
        out
    }

    /// Cache-blocked matmul of rows `lo..hi` of `self` against `b`,
    /// returned as a flat `(hi-lo) × b.cols` row-major buffer.
    fn matmul_rows(&self, b: &Matrix, lo: usize, hi: usize) -> Vec<f32> {
        let (k, n) = (self.cols, b.cols);
        let rows = hi - lo;
        let mut c = vec![0.0f32; rows * n];
        const BK: usize = 64;
        for kb in (0..k).step_by(BK) {
            let kend = (kb + BK).min(k);
            for i in 0..rows {
                let arow = &self.data[(lo + i) * k..(lo + i + 1) * k];
                let crow = &mut c[i * n..(i + 1) * n];
                for kk in kb..kend {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let brow = &b.data[kk * n..(kk + 1) * n];
                    for j in 0..n {
                        crow[j] += a * brow[j];
                    }
                }
            }
        }
        c
    }

    /// Cache-blocked matmul: C = A · B.
    pub fn matmul(&self, b: &Matrix) -> Result<Matrix> {
        self.matmul_par(b, Parallel::serial())
    }

    /// [`Matrix::matmul`] with output rows chunked across `par` workers.
    /// Falls back to inline execution below the kernel work cutoff.
    pub fn matmul_par(&self, b: &Matrix, par: Parallel) -> Result<Matrix> {
        if self.cols != b.rows {
            return Err(Error::Data(format!(
                "matmul {}x{} · {}x{}",
                self.rows, self.cols, b.rows, b.cols
            )));
        }
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let par = par.for_work(m.saturating_mul(k).saturating_mul(n));
        let chunks = par.par_chunks(m, |r| self.matmul_rows(b, r.start, r.end));
        Ok(Matrix { rows: m, cols: n, data: concat_chunks(chunks, m * n) })
    }

    /// C = Aᵀ · B without materializing Aᵀ (gradient contraction).
    pub fn matmul_at_b(&self, b: &Matrix) -> Result<Matrix> {
        self.matmul_at_b_par(b, Parallel::serial())
    }

    /// [`Matrix::matmul_at_b`] with the output rows (the contraction's `k`
    /// dimension) chunked across `par` workers. Each output cell keeps the
    /// serial accumulation order over samples, so the result is bitwise
    /// identical at any thread count.
    pub fn matmul_at_b_par(&self, b: &Matrix, par: Parallel) -> Result<Matrix> {
        if self.rows != b.rows {
            return Err(Error::Data("matmul_at_b row mismatch".into()));
        }
        let (m, k, n) = (self.rows, self.cols, b.cols);
        let par = par.for_work(m.saturating_mul(k).saturating_mul(n));
        let chunks = par.par_chunks(k, |range| {
            let mut c = vec![0.0f32; range.len() * n];
            for i in 0..m {
                let arow = &self.data[i * k..(i + 1) * k];
                let brow = &b.data[i * n..(i + 1) * n];
                for (kc, kk) in range.clone().enumerate() {
                    let a = arow[kk];
                    if a == 0.0 {
                        continue;
                    }
                    let crow = &mut c[kc * n..(kc + 1) * n];
                    for j in 0..n {
                        crow[j] += a * brow[j];
                    }
                }
            }
            c
        });
        Ok(Matrix { rows: k, cols: n, data: concat_chunks(chunks, k * n) })
    }

    /// Elementwise in-place map.
    pub fn map_inplace(&mut self, f: impl Fn(f32) -> f32) {
        for v in &mut self.data {
            *v = f(*v);
        }
    }

    /// Elementwise binary combine into a new matrix.
    pub fn zip(&self, other: &Matrix, f: impl Fn(f32, f32) -> f32) -> Result<Matrix> {
        if self.shape() != other.shape() {
            return Err(Error::Data("zip shape mismatch".into()));
        }
        let data = self
            .data
            .iter()
            .zip(&other.data)
            .map(|(&a, &b)| f(a, b))
            .collect();
        Ok(Matrix { rows: self.rows, cols: self.cols, data })
    }

    /// Add a row-vector bias to every row.
    pub fn add_bias(&self, bias: &[f32]) -> Result<Matrix> {
        if bias.len() != self.cols {
            return Err(Error::Data("bias width mismatch".into()));
        }
        let mut out = self.clone();
        for r in 0..out.rows {
            for (v, b) in out.row_mut(r).iter_mut().zip(bias) {
                *v += b;
            }
        }
        Ok(out)
    }

    /// Column sums (db = Σ rows).
    pub fn col_sums(&self) -> Vec<f32> {
        let mut out = vec![0.0; self.cols];
        for r in 0..self.rows {
            for (o, v) in out.iter_mut().zip(self.row(r)) {
                *o += v;
            }
        }
        out
    }

    /// Frobenius norm.
    pub fn frob_norm(&self) -> f32 {
        self.data.iter().map(|v| v * v).sum::<f32>().sqrt()
    }

    /// Max |a-b| against another matrix.
    pub fn max_abs_diff(&self, other: &Matrix) -> f32 {
        assert_eq!(self.shape(), other.shape());
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f32::max)
    }

    /// Z-score normalize columns in place; returns (means, stds).
    pub fn standardize(&mut self) -> (Vec<f32>, Vec<f32>) {
        let n = self.rows.max(1) as f32;
        let mut means = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for (m, v) in means.iter_mut().zip(self.row(r)) {
                *m += v;
            }
        }
        for m in &mut means {
            *m /= n;
        }
        let mut stds = vec![0.0f32; self.cols];
        for r in 0..self.rows {
            for c in 0..self.cols {
                let d = self.get(r, c) - means[c];
                stds[c] += d * d;
            }
        }
        for s in &mut stds {
            *s = (*s / n).sqrt().max(1e-6);
        }
        for r in 0..self.rows {
            for c in 0..self.cols {
                let v = (self.get(r, c) - means[c]) / stds[c];
                self.set(r, c, v);
            }
        }
        (means, stds)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn m(rows: usize, cols: usize, v: &[f32]) -> Matrix {
        Matrix::from_vec(rows, cols, v.to_vec()).unwrap()
    }

    #[test]
    fn matmul_known() {
        let a = m(2, 3, &[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        let b = m(3, 2, &[7.0, 8.0, 9.0, 10.0, 11.0, 12.0]);
        let c = a.matmul(&b).unwrap();
        assert_eq!(c.data(), &[58.0, 64.0, 139.0, 154.0]);
    }

    #[test]
    fn matmul_shape_checked() {
        let a = m(2, 3, &[0.0; 6]);
        assert!(a.matmul(&m(2, 2, &[0.0; 4])).is_err());
    }

    #[test]
    fn at_b_matches_explicit_transpose() {
        let mut rng = crate::util::rng::Rng::new(1);
        let a = Matrix::from_fn(5, 4, |_, _| rng.gaussian_f32());
        let b = Matrix::from_fn(5, 3, |_, _| rng.gaussian_f32());
        let fast = a.matmul_at_b(&b).unwrap();
        let slow = a.transpose().matmul(&b).unwrap();
        assert!(fast.max_abs_diff(&slow) < 1e-5);
    }

    #[test]
    fn hcat_and_select() {
        let a = m(2, 2, &[1.0, 2.0, 5.0, 6.0]);
        let b = m(2, 1, &[3.0, 7.0]);
        let c = Matrix::hcat(&[&a, &b]).unwrap();
        assert_eq!(c.row(0), &[1.0, 2.0, 3.0]);
        assert_eq!(c.row(1), &[5.0, 6.0, 7.0]);
        assert_eq!(c.select_cols(1, 3).row(0), &[2.0, 3.0]);
        assert_eq!(c.select_rows(&[1]).row(0), &[5.0, 6.0, 7.0]);
    }

    #[test]
    fn padding() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let p = a.pad_cols(4);
        assert_eq!(p.row(0), &[1.0, 2.0, 0.0, 0.0]);
        let q = a.pad_rows(3);
        assert_eq!(q.row(2), &[0.0, 0.0]);
    }

    #[test]
    fn bias_and_sums() {
        let a = m(2, 2, &[1.0, 2.0, 3.0, 4.0]);
        let c = a.add_bias(&[10.0, 20.0]).unwrap();
        assert_eq!(c.data(), &[11.0, 22.0, 13.0, 24.0]);
        assert_eq!(a.col_sums(), vec![4.0, 6.0]);
    }

    #[test]
    fn standardize_zero_mean_unit_var() {
        let mut rng = crate::util::rng::Rng::new(2);
        let mut a = Matrix::from_fn(200, 3, |_, c| 5.0 * rng.gaussian_f32() + c as f32);
        a.standardize();
        let means = {
            let mut v = vec![0.0f32; 3];
            for r in 0..200 {
                for c in 0..3 {
                    v[c] += a.get(r, c);
                }
            }
            v.iter().map(|x| x / 200.0).collect::<Vec<_>>()
        };
        for c in 0..3 {
            assert!(means[c].abs() < 1e-4, "col {c} mean {}", means[c]);
        }
    }

    #[test]
    fn transpose_involution() {
        let mut rng = crate::util::rng::Rng::new(3);
        let a = Matrix::from_fn(4, 7, |_, _| rng.gaussian_f32());
        assert_eq!(a.transpose().transpose(), a);
    }

    #[test]
    fn matmul_par_bitwise_matches_serial() {
        // 160·96·80 ≈ 1.2M flops — comfortably above PAR_MIN_WORK, so the
        // chunked path really runs; row-chunking must be bitwise exact.
        let mut rng = crate::util::rng::Rng::new(10);
        let a = Matrix::from_fn(160, 96, |_, _| rng.gaussian_f32());
        let b = Matrix::from_fn(96, 80, |_, _| rng.gaussian_f32());
        let serial = a.matmul(&b).unwrap();
        for t in [2usize, 4, 7] {
            let par = a.matmul_par(&b, Parallel::new(t)).unwrap();
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn matmul_at_b_par_bitwise_matches_serial() {
        let mut rng = crate::util::rng::Rng::new(11);
        let a = Matrix::from_fn(200, 64, |_, _| rng.gaussian_f32());
        let b = Matrix::from_fn(200, 48, |_, _| rng.gaussian_f32());
        let serial = a.matmul_at_b(&b).unwrap();
        for t in [2usize, 4, 8] {
            let par = a.matmul_at_b_par(&b, Parallel::new(t)).unwrap();
            assert_eq!(par, serial, "threads={t}");
        }
    }

    #[test]
    fn matmul_par_shape_checked() {
        let a = m(2, 3, &[0.0; 6]);
        assert!(a.matmul_par(&m(2, 2, &[0.0; 4]), Parallel::new(4)).is_err());
        assert!(a.matmul_at_b_par(&m(3, 2, &[0.0; 6]), Parallel::new(4)).is_err());
    }
}
