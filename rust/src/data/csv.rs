//! Minimal CSV reader/writer.
//!
//! Lets users point the CLI at their own numeric CSVs (last column =
//! label), and lets benches dump series for plotting. Handles quoted
//! fields and CRLF; numeric parsing is strict.

use std::io::{BufRead, BufReader, Read, Write};

use crate::data::{Dataset, Matrix, Task};
use crate::error::{Error, Result};

/// Parse one CSV record honoring double quotes.
pub fn parse_line(line: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut cur = String::new();
    let mut in_quotes = false;
    let mut chars = line.chars().peekable();
    while let Some(c) = chars.next() {
        match c {
            '"' if in_quotes && chars.peek() == Some(&'"') => {
                cur.push('"');
                chars.next();
            }
            '"' => in_quotes = !in_quotes,
            ',' if !in_quotes => out.push(std::mem::take(&mut cur)),
            '\r' => {}
            c => cur.push(c),
        }
    }
    out.push(cur);
    out
}

/// Read a numeric CSV into (header, rows).
pub fn read_numeric(reader: impl Read, has_header: bool) -> Result<(Vec<String>, Vec<Vec<f32>>)> {
    let buf = BufReader::new(reader);
    let mut header = Vec::new();
    let mut rows: Vec<Vec<f32>> = Vec::new();
    for (i, line) in buf.lines().enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let fields = parse_line(&line);
        if i == 0 && has_header {
            header = fields;
            continue;
        }
        let row = fields
            .iter()
            .map(|f| {
                f.trim()
                    .parse::<f32>()
                    .map_err(|_| Error::Data(format!("line {}: bad number {f:?}", i + 1)))
            })
            .collect::<Result<Vec<f32>>>()?;
        if let Some(first) = rows.first() {
            if first.len() != row.len() {
                return Err(Error::Data(format!(
                    "line {}: {} fields, expected {}",
                    i + 1,
                    row.len(),
                    first.len()
                )));
            }
        }
        rows.push(row);
    }
    Ok((header, rows))
}

/// Load a dataset from CSV: all columns but the last are features, the
/// last column is the label.
pub fn load_dataset(reader: impl Read, name: &str, task: Task, has_header: bool) -> Result<Dataset> {
    let (_, rows) = read_numeric(reader, has_header)?;
    if rows.is_empty() {
        return Err(Error::Data("empty csv".into()));
    }
    let d = rows[0].len() - 1;
    if d == 0 {
        return Err(Error::Data("csv needs >= 2 columns".into()));
    }
    let mut x = Vec::with_capacity(rows.len() * d);
    let mut y = Vec::with_capacity(rows.len());
    for r in &rows {
        x.extend_from_slice(&r[..d]);
        y.push(r[d]);
    }
    Dataset::new(name, Matrix::from_vec(rows.len(), d, x)?, y, task)
}

/// Write rows of f64 (benches dump series with this).
pub fn write_rows(w: &mut impl Write, header: &[&str], rows: &[Vec<f64>]) -> Result<()> {
    writeln!(w, "{}", header.join(","))?;
    for r in rows {
        let line: Vec<String> = r.iter().map(|v| format!("{v}")).collect();
        writeln!(w, "{}", line.join(","))?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_quotes_and_commas() {
        assert_eq!(parse_line(r#"a,"b,c",d"#), vec!["a", "b,c", "d"]);
        assert_eq!(parse_line(r#""he said ""hi""",2"#), vec![r#"he said "hi""#, "2"]);
    }

    #[test]
    fn reads_numeric_with_header() {
        let csv = "a,b,label\n1,2,0\n3,4,1\n";
        let (h, rows) = read_numeric(csv.as_bytes(), true).unwrap();
        assert_eq!(h, vec!["a", "b", "label"]);
        assert_eq!(rows, vec![vec![1.0, 2.0, 0.0], vec![3.0, 4.0, 1.0]]);
    }

    #[test]
    fn rejects_ragged() {
        let csv = "1,2\n3\n";
        assert!(read_numeric(csv.as_bytes(), false).is_err());
    }

    #[test]
    fn rejects_non_numeric() {
        assert!(read_numeric("1,x\n".as_bytes(), false).is_err());
    }

    #[test]
    fn dataset_roundtrip() {
        let csv = "1,2,0\n3,4,1\n5,6,0\n";
        let d = load_dataset(
            csv.as_bytes(),
            "t",
            Task::Classification { n_classes: 2 },
            false,
        )
        .unwrap();
        assert_eq!(d.n(), 3);
        assert_eq!(d.d(), 2);
        assert_eq!(d.y, vec![0.0, 1.0, 0.0]);
    }

    #[test]
    fn writes_rows() {
        let mut out = Vec::new();
        write_rows(&mut out, &["x", "y"], &[vec![1.0, 2.5]]).unwrap();
        assert_eq!(String::from_utf8(out).unwrap(), "x,y\n1,2.5\n");
    }
}
