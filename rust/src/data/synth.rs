//! Synthetic dataset generators standing in for the paper's six datasets.
//!
//! No network access on this image, so we can't pull the Kaggle/UCI data.
//! Each generator matches its dataset's *shape* (instances × features ×
//! classes, Table 1) and is tuned so full-data model accuracy lands near
//! the paper's Table 2 value. Coreset behaviour depends on the redundancy
//! structure (how many samples say the same thing), which the generators
//! control explicitly through per-class mode counts and noise:
//! RI is near-separable and highly redundant (the paper compresses it by
//! 98.4% at 100% accuracy), BP is 4-class with heavy overlap (66%), etc.
//!
//! `scale` rescales instance counts (benches use scale < 1 for fast mode,
//! 1.0 reproduces the paper's sizes).

use crate::data::{Dataset, Matrix, Task};
use crate::util::rng::Rng;

/// Paper dataset identities (Table 1).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PaperDataset {
    /// Bank customer churn: 10K × 11, binary.
    Ba,
    /// Mushrooms: 8K × 22, binary.
    Mu,
    /// Rice: 18K × 11, binary, extremely redundant/separable.
    Ri,
    /// Higgs (subsampled): 100K × 32, binary.
    Hi,
    /// BodyPerformance: 13K × 11, 4 classes, heavy overlap.
    Bp,
    /// YearPredictionMSD: 510K × 90, regression.
    Yp,
}

impl PaperDataset {
    pub const ALL: [PaperDataset; 6] = [
        PaperDataset::Ba,
        PaperDataset::Mu,
        PaperDataset::Ri,
        PaperDataset::Hi,
        PaperDataset::Bp,
        PaperDataset::Yp,
    ];

    pub fn name(&self) -> &'static str {
        match self {
            PaperDataset::Ba => "BA",
            PaperDataset::Mu => "MU",
            PaperDataset::Ri => "RI",
            PaperDataset::Hi => "HI",
            PaperDataset::Bp => "BP",
            PaperDataset::Yp => "YP",
        }
    }

    /// (instances, features, classes; 0 = regression) per Table 1.
    pub fn shape(&self) -> (usize, usize, usize) {
        match self {
            PaperDataset::Ba => (10_000, 11, 2),
            PaperDataset::Mu => (8_000, 22, 2),
            PaperDataset::Ri => (18_000, 11, 2),
            PaperDataset::Hi => (100_000, 32, 2),
            PaperDataset::Bp => (13_000, 11, 4),
            PaperDataset::Yp => (510_000, 90, 0),
        }
    }

    /// Generate the synthetic stand-in at `scale` of the paper size.
    pub fn generate(&self, scale: f64, rng: &mut Rng) -> Dataset {
        let (n0, d, _k) = self.shape();
        let n = ((n0 as f64 * scale).round() as usize).max(64);
        match self {
            // (modes/class, separation, noise) tuned per dataset character.
            PaperDataset::Ba => blobs(self.name(), n, d, 2, 3, 2.4, 1.0, rng),
            PaperDataset::Mu => blobs(self.name(), n, d, 2, 4, 3.2, 0.8, rng),
            // RI: few tight, well-separated modes → massive redundancy.
            PaperDataset::Ri => blobs(self.name(), n, d, 2, 2, 6.0, 0.45, rng),
            PaperDataset::Hi => blobs(self.name(), n, d, 2, 5, 3.0, 0.9, rng),
            // BP: 4 classes, overlapping → caps accuracy in the 60s.
            PaperDataset::Bp => blobs(self.name(), n, d, 4, 3, 1.05, 1.35, rng),
            PaperDataset::Yp => regression(self.name(), n, d, rng),
        }
    }
}

/// Gaussian-mixture classification generator.
///
/// Each class gets `modes` Gaussian modes with centers sampled on a sphere
/// of radius `sep`; samples add N(0, noise²) per-dimension jitter. Labels
/// are the generating class. Redundancy grows as `noise/sep` shrinks.
#[allow(clippy::too_many_arguments)]
pub fn blobs(
    name: &str,
    n: usize,
    d: usize,
    classes: usize,
    modes: usize,
    sep: f32,
    noise: f32,
    rng: &mut Rng,
) -> Dataset {
    // Sample mode centers.
    let mut centers = Vec::with_capacity(classes * modes);
    for _ in 0..classes * modes {
        let mut c: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let norm = c.iter().map(|v| v * v).sum::<f32>().sqrt().max(1e-6);
        for v in &mut c {
            *v *= sep / norm;
        }
        centers.push(c);
    }
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for i in 0..n {
        let class = i % classes; // balanced classes
        let mode = rng.below_usize(modes);
        let center = &centers[class * modes + mode];
        for &cv in center.iter() {
            x.push(cv + noise * rng.gaussian_f32());
        }
        y.push(class as f32);
    }
    // Shuffle rows so class order is not systematic.
    let mut idx: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut idx);
    let xm = Matrix::from_vec(n, d, x).unwrap();
    let ds = Dataset::new(
        name,
        xm,
        y,
        Task::Classification { n_classes: classes },
    )
    .unwrap();
    ds.subset(&idx)
}

/// Linear-plus-interaction regression generator (YearPrediction-like):
/// y = w·x + 0.5·(x₀·x₁) + ε, standardized targets.
pub fn regression(name: &str, n: usize, d: usize, rng: &mut Rng) -> Dataset {
    let w: Vec<f32> = (0..d).map(|_| rng.gaussian_f32() / (d as f32).sqrt()).collect();
    let mut x = Vec::with_capacity(n * d);
    let mut y = Vec::with_capacity(n);
    for _ in 0..n {
        let row: Vec<f32> = (0..d).map(|_| rng.gaussian_f32()).collect();
        let mut t: f32 = row.iter().zip(&w).map(|(a, b)| a * b).sum();
        t += 0.5 * row[0] * row[1.min(d - 1)];
        t += 0.3 * rng.gaussian_f32();
        x.extend_from_slice(&row);
        y.push(t);
    }
    Dataset::new(name, Matrix::from_vec(n, d, x).unwrap(), y, Task::Regression).unwrap()
}

/// Indicator sets for MPSI benches (paper §5.3): `m` clients, `n` items
/// each, with `overlap` fraction shared across all clients; each client's
/// list is independently shuffled.
pub fn mpsi_indicator_sets(m: usize, n: usize, overlap: f64, rng: &mut Rng) -> Vec<Vec<u64>> {
    mpsi_indicator_sets_sized(&vec![n; m], overlap, rng)
}

/// Like [`mpsi_indicator_sets`] but with per-client sizes (Fig. 7c uses
/// client i holding 10000·(i+1) items). The common core has
/// `overlap × min(sizes)` items so it fits in every client.
pub fn mpsi_indicator_sets_sized(sizes: &[usize], overlap: f64, rng: &mut Rng) -> Vec<Vec<u64>> {
    assert!(!sizes.is_empty());
    let min_n = *sizes.iter().min().unwrap();
    let n_common = ((min_n as f64) * overlap).round() as usize;
    // Disjoint id spaces: common ids first, then per-client unique ranges.
    let common: Vec<u64> = (0..n_common as u64).collect();
    let mut next_unique = n_common as u64;
    let mut sets = Vec::with_capacity(sizes.len());
    for &n in sizes {
        let mut s = common.clone();
        let uniq = n - n_common;
        s.extend(next_unique..next_unique + uniq as u64);
        next_unique += uniq as u64;
        rng.shuffle(&mut s);
        sets.push(s);
    }
    sets
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::psi::oracle_intersection;

    #[test]
    fn shapes_match_table1_at_scale() {
        let mut rng = Rng::new(1);
        for ds in PaperDataset::ALL {
            let (n0, d, k) = ds.shape();
            let data = ds.generate(0.01, &mut rng);
            assert_eq!(data.d(), d, "{}", ds.name());
            let expect_n = ((n0 as f64 * 0.01).round() as usize).max(64);
            assert_eq!(data.n(), expect_n);
            if k > 0 {
                assert_eq!(data.task.n_classes(), k);
                // All classes present.
                let mut seen = vec![false; k];
                for &y in &data.y {
                    seen[y as usize] = true;
                }
                assert!(seen.iter().all(|&s| s), "{}", ds.name());
            } else {
                assert_eq!(data.task, Task::Regression);
            }
        }
    }

    #[test]
    fn blobs_are_linearly_separable_when_far() {
        // sep >> noise ⇒ a trivial centroid classifier should ace it.
        let mut rng = Rng::new(2);
        let ds = blobs("t", 500, 6, 2, 1, 8.0, 0.3, &mut rng);
        // Nearest-class-mean classifier.
        let mut means = vec![vec![0.0f32; 6]; 2];
        let mut counts = [0usize; 2];
        for i in 0..ds.n() {
            let c = ds.y[i] as usize;
            counts[c] += 1;
            for (m, v) in means[c].iter_mut().zip(ds.x.row(i)) {
                *m += v;
            }
        }
        for (m, &c) in means.iter_mut().zip(&counts) {
            for v in m.iter_mut() {
                *v /= c as f32;
            }
        }
        let mut correct = 0;
        for i in 0..ds.n() {
            let d0: f32 = ds.x.row(i).iter().zip(&means[0]).map(|(a, b)| (a - b) * (a - b)).sum();
            let d1: f32 = ds.x.row(i).iter().zip(&means[1]).map(|(a, b)| (a - b) * (a - b)).sum();
            let pred = if d1 < d0 { 1.0 } else { 0.0 };
            correct += (pred == ds.y[i]) as usize;
        }
        assert!(correct as f64 / ds.n() as f64 > 0.97);
    }

    #[test]
    fn mpsi_sets_have_exact_overlap() {
        let mut rng = Rng::new(3);
        let sets = mpsi_indicator_sets(5, 1000, 0.7, &mut rng);
        assert_eq!(sets.len(), 5);
        for s in &sets {
            assert_eq!(s.len(), 1000);
        }
        assert_eq!(oracle_intersection(&sets).len(), 700);
    }

    #[test]
    fn mpsi_sized_sets_match_fig7c_shape() {
        let mut rng = Rng::new(4);
        let sizes: Vec<usize> = (1..=4).map(|i| 100 * i).collect();
        let sets = mpsi_indicator_sets_sized(&sizes, 0.7, &mut rng);
        for (s, &n) in sets.iter().zip(&sizes) {
            assert_eq!(s.len(), n);
        }
        assert_eq!(oracle_intersection(&sets).len(), 70);
    }

    #[test]
    fn regression_targets_correlate_with_features() {
        let mut rng = Rng::new(5);
        let ds = regression("r", 2000, 8, &mut rng);
        // Var(y) should be dominated by signal, not the 0.3 noise.
        let mean = ds.y.iter().sum::<f32>() / ds.n() as f32;
        let var = ds.y.iter().map(|v| (v - mean) * (v - mean)).sum::<f32>() / ds.n() as f32;
        assert!(var > 0.5, "var {var}");
    }
}
